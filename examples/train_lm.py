"""End-to-end LM training example: a few hundred steps of a SmolLM-family
model through the full framework substrate — sharded train step, WSD/cosine
schedule, async checkpointing, deterministic resumable data, and a
mid-run injected failure to demonstrate checkpoint/restart recovery.

    PYTHONPATH=src python examples/train_lm.py            # quick (reduced)
    PYTHONPATH=src python examples/train_lm.py --full     # smollm-360m

The reduced config trains in a couple of minutes on CPU; --full is the real
360M config (use on accelerators).
"""

import argparse
import sys
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    argv = [
        "--arch", "smollm-360m",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--lr", "3e-3",
        "--microbatches", "2",
        "--ckpt-dir", ckpt,
        "--ckpt-every", "50",
        # drill: a node "fails" at step 120; the supervisor restores the
        # step-100 checkpoint and replays the data stream deterministically
        "--fail-at", "120",
        "--log-every", "20",
    ]
    if not args.full:
        argv.append("--smoke")
    print(f"[example] checkpoints in {ckpt}")
    train_main(argv)


if __name__ == "__main__":
    main()
