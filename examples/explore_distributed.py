"""Distributed SNP exploration: shard the computation-tree search over
many devices (hash-partitioned frontier + visited set, all_to_all
exchange), optionally with the neuron axis of every config sharded too
(``--plan neuron_axis``: frontier/archive rows carry only their device's
neuron slice and only halo segments cross devices — DESIGN.md §2).

Run with fake devices on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/explore_distributed.py

    # heavy-tailed graph (unbounded hubs), neuron-axis sharded frontier
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/explore_distributed.py \
            --graph power_law --plan neuron_axis

    # same sharded BFS, but each device steps its shard through the fused
    # sparse Pallas kernel (interpret mode on CPU; DESIGN.md §3)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/explore_distributed.py \
            --plan neuron_axis --backend sparse_pallas

    # let the query planner pick backend/encoding/blocks for the workload
    # (DESIGN.md §3 "Planner & autotuner"): prints the chosen config and
    # its predicted vs measured step cost, then explores with it
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/explore_distributed.py --plan auto
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SystemPlan, available_backends, compile_system,
                        explore, get_backend, resolve_kernel)
from repro.core import autotune
from repro.core.distributed import explore_distributed
from repro.core.generators import power_law, random_system, scaled_pi
from repro.sharding import neuron_axis

GRAPHS = ("random", "power_law")


def _graph(name: str, ndev: int):
    if name == "power_law":
        # Unbounded hubs (max_in=None): the heavy-tailed in-degree family
        # the hybrid ELL+COO plan targets; deterministic in its seed.
        return power_law(64, 4, seed=5), dict(
            max_steps=6, frontier_cap=4096 // ndev,
            visited_cap=32768 // ndev, max_branches=64)
    return random_system(64, 2, 0.08, seed=5), dict(
        max_steps=8, frontier_cap=8192 // ndev,
        visited_cap=65536 // ndev, max_branches=64)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--graph", choices=GRAPHS, default="random",
                    help="64-neuron comparison topology")
    ap.add_argument("--plan", choices=("dense_rows", "neuron_axis", "auto"),
                    default="dense_rows",
                    help="dense_rows: hash-partitioned full config rows; "
                         "neuron_axis: per-device neuron slices + halo "
                         "exchange (SystemPlan sharding); auto: let the "
                         "query planner pick backend/encoding/blocks for "
                         "the workload, then explore dense_rows with them")
    ap.add_argument("--backend", choices=available_backends(),
                    default="ref",
                    help="per-shard step backend (registry name); under "
                         "--plan neuron_axis the fused kernels consume "
                         "each device's extended-index shard encoding "
                         "(DESIGN.md §3 'Kernel lowering')")
    args = ap.parse_args()

    ndev = len(jax.devices())
    print(f"devices: {ndev}, backend: {args.backend}")

    print("\n-- paper's Π scaled x8 (24 neurons, 40 rules) --")
    comp = compile_system(scaled_pi(8))
    t0 = time.time()
    res = explore_distributed(comp, max_steps=6, frontier_cap=256,
                              visited_cap=8192, max_branches=64)
    print(f"distributed: {res.num_discovered} configs in "
          f"{res.steps} levels, {time.time()-t0:.2f}s "
          f"(overflow: {res.branch_overflow})")

    system, kw = _graph(args.graph, ndev)
    auto_plan = None
    backend_name = args.backend
    if args.plan == "auto":
        # Plan at the workload the exploration below actually runs
        # (B = global frontier cap, T = branch cap), then show the
        # decision and how well the cost model predicted it.
        auto_plan = SystemPlan.for_system(
            system, workload=(kw["frontier_cap"], kw["max_branches"]),
            mode="auto")
        backend_name = auto_plan.backend or backend_name
        k = auto_plan.kernel
        print(f"\nplanner pick: backend={backend_name} "
              f"encoding={auto_plan.encoding} "
              f"hub_threshold={auto_plan.hub_threshold} "
              f"blocks=(bb={k.block_b if k else None}, "
              f"bt={k.block_t if k else None})")
        B, T = min(kw["frontier_cap"], 256), kw["max_branches"]
        sig = autotune.signature_of(system, workload=(B, T))
        predicted = autotune.predict_us(sig, backend_name)
        be = resolve_kernel(get_backend(backend_name), auto_plan)
        comp = be.compile(system, plan=auto_plan)
        cfgs = jnp.asarray(np.random.default_rng(0).integers(
            0, 4, size=(B, system.num_neurons)), jnp.int32)

        @jax.jit
        def step(c):
            out = be.expand(c, comp, max_branches=T)
            return out.configs, out.valid
        jax.block_until_ready(step(cfgs))            # compile + warmup
        t0 = time.perf_counter()
        jax.block_until_ready(step(cfgs))
        measured = (time.perf_counter() - t0) * 1e6
        pred = "n/a" if predicted is None else f"{predicted:.0f}us"
        print(f"step cost at (B={B}, T={T}): predicted {pred}, "
              f"measured {measured:.0f}us")
    if backend_name in ("pallas", "sparse_pallas"):
        # Interpret-mode kernel emulation on CPU: keep the demo snappy
        # (on a TPU with interpret=False the full caps are the point).
        kw = {**kw, "frontier_cap": max(kw["frontier_cap"] // 16, 8),
              "visited_cap": max(kw["visited_cap"] // 16, 64),
              "max_steps": min(kw["max_steps"], 4)}
    print(f"\n-- {system.name} ({args.plan}, backend={backend_name}) --")
    t0 = time.time()
    if args.plan == "neuron_axis":
        # Global frontier bookkeeping, per-device neuron slices; the
        # backend steps each shard (jnp math or fused kernel).
        res = explore_distributed(system, plan=neuron_axis(ndev),
                                  backend=args.backend,
                                  **{**kw, "frontier_cap": kw["frontier_cap"]
                                     * ndev})
    elif args.plan == "auto":
        # Hash-partitioned dense_rows exploration under the planner's
        # chosen backend/encoding/blocks (the plan carries all three).
        res = explore_distributed(system, plan=auto_plan, **kw)
    else:
        # Pass the raw system: each backend compiles its own encoding
        # (a pre-compiled dense object would break the sparse family).
        res = explore_distributed(system, backend=args.backend, **kw)
    dt = time.time() - t0
    single = explore(compile_system(system),
                     **{**kw, "frontier_cap": kw["frontier_cap"] * ndev,
                        "visited_cap": kw["visited_cap"] * ndev})
    agree = ({tuple(r) for r in res.configs}
             == {tuple(r) for r in single.configs})
    print(f"distributed {res.num_discovered} vs single "
          f"{single.num_discovered} in {dt:.2f}s; sets agree: {agree} "
          f"(overflow d={res.frontier_overflow} s={single.frontier_overflow})")


if __name__ == "__main__":
    main()
