"""Distributed SNP exploration: shard the computation-tree search over
many devices (hash-partitioned frontier + visited set, all_to_all
exchange).

Run with fake devices on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/explore_distributed.py
"""

import time

import jax

from repro.core import compile_system, explore
from repro.core.distributed import explore_distributed
from repro.core.generators import random_system, scaled_pi


def main():
    ndev = len(jax.devices())
    print(f"devices: {ndev}")

    print("\n-- paper's Π scaled x8 (24 neurons, 40 rules) --")
    comp = compile_system(scaled_pi(8))
    t0 = time.time()
    res = explore_distributed(comp, max_steps=6, frontier_cap=256,
                              visited_cap=8192, max_branches=64)
    print(f"distributed: {res.num_discovered} configs in "
          f"{res.steps} levels, {time.time()-t0:.2f}s "
          f"(overflow: {res.branch_overflow})")

    print("\n-- random 64-neuron system --")
    comp = compile_system(random_system(64, 2, 0.08, seed=5))
    t0 = time.time()
    res = explore_distributed(comp, max_steps=8,
                              frontier_cap=8192 // ndev,
                              visited_cap=65536 // ndev, max_branches=64)
    single = explore(comp, max_steps=8, frontier_cap=8192,
                     visited_cap=65536, max_branches=64)
    agree = ({tuple(r) for r in res.configs}
             == {tuple(r) for r in single.configs})
    print(f"distributed {res.num_discovered} vs single "
          f"{single.num_discovered}; sets agree: {agree} "
          f"(overflow d={res.frontier_overflow} s={single.frontier_overflow})")


if __name__ == "__main__":
    main()
