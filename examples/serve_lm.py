"""Batched serving example: prefill a request batch, greedy-decode a
continuation, for any assigned architecture (reduced configs on CPU).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch musicgen-medium
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])


if __name__ == "__main__":
    main()
