"""Quickstart: simulate the paper's SNP system Π end-to-end.

Reproduces the §5 simulation run of Cabarle–Adorna–Martínez-del-Amor
(2011): loads Π (Fig. 1), prints its spiking transition matrix (eq. 1),
explores the computation tree breadth-first with on-device dedup, prints
the generated configuration list in the paper's own format, and verifies
the ℕ∖{1} generation property under exact semantics.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (compile_system, emission_gaps, explore, paper_pi,
                        successor_set)


def main():
    system = paper_pi(covering=True)
    comp = compile_system(system)

    print("**** SN P system simulation run STARTS here ****")
    print(system.describe())
    print("\nSpiking transition matrix M_Π (paper eq. 1):")
    print(np.asarray(comp.M))

    print("\nSpiking vectors at C0 =", list(system.initial_spikes),
          "->", [c for c, _ in successor_set(comp, system.initial_spikes)])

    res = explore(comp, max_steps=16, frontier_cap=128, visited_cap=2048,
                  max_branches=16)
    print(f"\nExplored {res.steps} BFS levels, "
          f"{res.num_discovered} distinct configurations")
    print("allGenCk =", res.as_strings()[:48])

    print("\n-- semantics check: Π generates ℕ∖{1} (exact mode) --")
    gaps = emission_gaps(compile_system(paper_pi(covering=False)),
                         max_time=25, max_gap=12)
    print("observed spike-train gaps:", sorted(gaps))
    assert 1 not in gaps and set(range(2, 12)) <= gaps
    print("**** SN P system simulation run ENDS here ****")


if __name__ == "__main__":
    main()
