"""Training substrate: AdamW + schedules, microbatched train step,
gradient compression with error feedback."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, make_schedule
from .train_step import TrainState, init_train_state, make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "make_schedule",
           "TrainState", "init_train_state", "make_train_step"]
