"""AdamW + LR schedules (cosine, WSD) — self-contained (no optax).

Optimizer state is a pytree congruent with params (first/second moments in
f32), so the sharding plan's param specs apply verbatim to the state: the
optimizer shards exactly like FSDP params, which is what makes 314B-scale
training state fit (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "make_schedule",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1       # WSD: fraction of steps in final decay


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def make_schedule(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    w, total = cfg.warmup_steps, cfg.total_steps

    def cosine(step):
        frac = jnp.clip((step - w) / max(total - w, 1), 0.0, 1.0)
        return 0.5 * (1 + jnp.cos(jnp.pi * frac))

    def wsd(step):
        # warmup -> stable plateau -> short decay tail (MiniCPM)
        decay_steps = max(int(total * cfg.decay_frac), 1)
        start = total - decay_steps
        frac = jnp.clip((step - start) / decay_steps, 0.0, 1.0)
        return jnp.where(step < start, 1.0, 1.0 - frac * (1.0 - 0.1))

    def constant(step):
        return jnp.ones_like(step, jnp.float32)

    shape_fn = {"cosine": cosine, "wsd": wsd, "constant": constant}[cfg.schedule]

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = jnp.clip(step / max(w, 1), 0.0, 1.0)
        return cfg.lr * warm * shape_fn(step)

    return schedule


def adamw_update(
    cfg: AdamWConfig, grads, state: AdamWState, params,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step with global-norm clipping.  Returns
    (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = make_schedule(cfg)(count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(new_m, new_v, count), metrics
