"""Train step factory: microbatched gradient accumulation, remat policy,
mixed precision, optional gradient compression — jit/pjit-ready.

The returned ``train_step(params, opt_state, batch)`` is a pure function;
launchers wrap it in ``jax.jit`` with in/out shardings from the plan.  Grad
accumulation runs as a ``lax.scan`` over microbatches (activation memory =
one microbatch), with f32 accumulators sharded like the params.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import loss_fn

from .compression import compress_grads, ef_init
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_train_state"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[Any]            # error-feedback residual (compression)
    step: jnp.ndarray


def init_train_state(params, opt_cfg: AdamWConfig,
                     compression: bool = False) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        ef=ef_init(params) if compression else None,
        step=jnp.zeros((), jnp.int32),
    )


def _split_microbatches(batch: Dict, k: int) -> Dict:
    """(B, ...) -> (k, B/k, ...) on batch-leading leaves; positions with a
    leading plane dim (3, B, S) are handled specially."""

    def split(path, x):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "positions" and x.ndim == 3 and x.shape[0] == 3:
            return jnp.moveaxis(
                x.reshape(3, k, x.shape[1] // k, *x.shape[2:]), 1, 0)
        return x.reshape(k, x.shape[0] // k, *x.shape[1:])

    return jax.tree_util.tree_map_with_path(split, batch)


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    remat: str = "full",
    attn_impl: str = "xla",
    constrain: Callable = lambda t, k: t,
    compression: bool = False,
    aux_loss_weight: float = 0.01,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``."""

    def loss(params, mb):
        return loss_fn(params, cfg, mb, attn_impl=attn_impl,
                       constrain=constrain, remat=remat,
                       aux_loss_weight=aux_loss_weight)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict):
        params = state.params

        if microbatches == 1:
            (l, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (l, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), ms = jax.lax.scan(
                acc_body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            l = lsum / microbatches
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        ef = state.ef
        if compression:
            grads, ef = compress_grads(grads, ef)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, params)
        metrics = {**metrics, **opt_metrics, "loss": l}
        return TrainState(new_params, new_opt, ef, state.step + 1), metrics

    return train_step
