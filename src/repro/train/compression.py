"""Gradient compression with error feedback (distributed-optimization trick).

Int8 block-wise quantization of gradients before the (XLA-inserted)
cross-replica reduction, with an error-feedback accumulator so quantization
noise is re-injected next step instead of lost (1-bit-Adam / EF-SGD
lineage).  At 512 chips the gradient all-reduce moves ~4x fewer bytes in
int8 than bf16 — the collective roofline term shrinks accordingly (see
EXPERIMENTS.md §Perf); convergence impact is bounded by the EF residual,
which tests assert decays.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compress_grads", "quantize_int8", "dequantize_int8"]

_BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    out = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape).astype(dtype)


def ef_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef_state):
    """Quantize (grad + residual) to int8 wire format; return the
    dequantized gradient actually applied and the new residual."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s, g.shape)
        return deq, target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
