"""Per-op byte/flop attribution for a compiled HLO module — the
"profiler" of the dry-run methodology (no hardware, so the lowered IR is
the profile).  Groups the loop-aware cost rollup by (op, shape) so the
§Perf loop can see exactly which tensors dominate a roofline term.
"""

from __future__ import annotations

import collections
import re
from typing import Counter, Dict, List, Tuple

from . import hlo_analyzer as H

__all__ = ["attribute_bytes", "attribute_flops", "top_table"]


def _walk(text: str):
    """Yields (comp, op, shape_str, bytes, flops) per instruction plus the
    computation multiplier map."""
    comp_ops: Dict[str, List[Tuple[str, float, float, str]]] = \
        collections.defaultdict(list)
    calls: Dict[str, List[Tuple[str, float]]] = collections.defaultdict(list)
    entry = None
    cur = None
    shapes: Dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "->" in line and line.endswith("{"):
            m = H._COMP_HEADER.match(line.strip())
            if m:
                cur = m.group(1)
                shapes = {}
                comp_ops.setdefault(cur, [])
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        m = H._INSTR.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        shapes[name] = shape_str
        out_b, out_dims = H._shape_info(shape_str)
        opn, opb = [], 0
        paren = line[line.index("(", line.index(op)) + 1:]
        for om in re.finditer(r"%([\w\.\-]+)", paren.split(")")[0]):
            opn.append(om.group(1))
            s = shapes.get(om.group(1))
            if s:
                opb += H._shape_info(s)[0]
        if op in H._ZERO_BYTE_OPS or op in ("while", "conditional", "call",
                                            "fusion"):
            b = 0.0
        elif op in H._SLICE_OPS:
            b = 2.0 * out_b
        elif op in H._UPDATE_OPS:
            upd = shapes.get(opn[1]) if len(opn) > 1 else None
            b = 2.0 * (H._shape_info(upd)[0] if upd else out_b)
        else:
            b = float(out_b + opb)
        fl = 0.0
        if op == "dot":
            cm = H._CONTRACT.search(line)
            contracted = 1
            if cm and opn and opn[0] in shapes:
                lhs = H._shape_info(shapes[opn[0]])[1]
                for d in cm.group(1).split(","):
                    if d and int(d) < len(lhs):
                        contracted *= lhs[int(d)]
            n_out = 1
            for d in out_dims:
                n_out *= d
            fl = 2.0 * n_out * contracted
        comp_ops[cur].append((op, b, fl, shape_str[:48]))
        if op == "while":
            t = H._TRIP.search(line)
            tr = float(int(t.group(1)) if t else 1)
            c = H._CALLEE.search(line)
            if c:
                calls[cur].append((c.group(1), tr))
        else:
            c = H._CALLEE.search(line)
            if c:
                calls[cur].append((c.group(1), 1.0))

    mult = {k: 0.0 for k in comp_ops}
    if entry:
        mult[entry] = 1.0
        for _ in range(64):
            new = {k: 0.0 for k in comp_ops}
            new[entry] = 1.0
            for n, cs in calls.items():
                m0 = mult.get(n, 0.0)
                if not m0:
                    continue
                for cal, cm in cs:
                    if cal in new:
                        new[cal] += m0 * cm
            if all(abs(new[k] - mult[k]) < 1e-9 for k in comp_ops):
                break
            mult = new
    return comp_ops, mult


def attribute_bytes(text: str) -> Counter:
    comp_ops, mult = _walk(text)
    agg: Counter = collections.Counter()
    for comp, ops in comp_ops.items():
        m0 = mult.get(comp, 0.0)
        for op, b, fl, sh in ops:
            agg[(op, sh)] += b * m0
    return agg


def attribute_flops(text: str) -> Counter:
    comp_ops, mult = _walk(text)
    agg: Counter = collections.Counter()
    for comp, ops in comp_ops.items():
        m0 = mult.get(comp, 0.0)
        for op, b, fl, sh in ops:
            if fl:
                agg[(op, sh)] += fl * m0
    return agg


def top_table(agg: Counter, n: int = 15, unit: float = 1e12,
              label: str = "TB") -> str:
    total = sum(agg.values())
    lines = [f"total = {total / unit:.2f} {label}"]
    for (op, sh), v in agg.most_common(n):
        lines.append(f"  {v / unit:8.2f} {label} {100 * v / total:5.1f}%  "
                     f"{op:22s} {sh}")
    return "\n".join(lines)
