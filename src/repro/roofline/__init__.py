"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (HW, CollectiveStats, analyze_compiled,
                       parse_collectives, roofline_terms)

__all__ = ["HW", "CollectiveStats", "analyze_compiled", "parse_collectives",
           "roofline_terms"]
