"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (brief §Roofline):

    compute    = HLO_FLOPs / (chips · 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips · 819 GB/s HBM)
    collective = comm_bytes / (chips · 50 GB/s ICI per link)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed for the
*per-device* SPMD program (verified against analytic 6·N·D in tests);
collective bytes are parsed from the optimized HLO text: for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take the op's tensor size and convert to per-device link bytes with the
standard ring-algorithm factors over its replica-group size.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms",
           "analyze_compiled"]

HW = {
    "flops": 197e12,     # bf16 FLOP/s per chip (TPU v5e)
    "hbm": 819e9,        # HBM bytes/s per chip
    "ici": 50e9,         # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction:  %name = <shape(s)> op-name(...)
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s*"
    r"(?P<op>[a-z0-9-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    tensor_bytes: Dict[str, int]     # summed op tensor sizes
    link_bytes: float                # per-device bytes over the wire
    details: List[Tuple[str, int, int]]  # (op, bytes, group)

    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    tbytes = {k: 0 for k in _COLLECTIVES}
    link = 0.0
    details = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        size = _shape_bytes(m.group("shape"))
        g = _group_size(line, default_group)
        counts[base] += 1
        tbytes[base] += size
        # ring-algorithm per-device wire bytes
        if base == "all-reduce":
            wire = 2 * size * (g - 1) / max(g, 1)
        elif base in ("all-gather", "reduce-scatter"):
            wire = size * (g - 1) / max(g, 1)
        elif base == "all-to-all":
            wire = size * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = size
        link += wire
        details.append((op, size, g))
    return CollectiveStats(counts=counts, tensor_bytes=tbytes,
                           link_bytes=link, details=details)


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float,
                   chips: int, model_flops: Optional[float] = None,
                   links_per_chip: int = 1) -> Dict[str, float]:
    """All terms in seconds.  FLOPs/bytes are per-device program numbers
    (XLA cost analysis of the SPMD-partitioned module), so the per-chip
    denominators apply directly."""
    compute = flops / HW["flops"]
    memory = hbm_bytes / HW["hbm"]
    collective = link_bytes / (HW["ici"] * links_per_chip)
    out = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bound": max(
            (("compute", compute), ("memory", memory),
             ("collective", collective)),
            key=lambda kv: kv[1])[0],
    }
    if model_flops:
        # model_flops is global; per-chip share:
        out["model_flops_per_chip"] = model_flops / chips
        out["useful_flops_frac"] = (model_flops / chips) / max(flops, 1.0)
    return out


def analyze_compiled(lowered, compiled, *, chips: int,
                     model_flops: Optional[float] = None,
                     default_group: Optional[int] = None) -> Dict:
    """Full record for one dry-run cell.

    FLOPs/bytes/collective traffic come from the loop-aware HLO analyzer
    (:mod:`.hlo_analyzer`) — XLA's own ``cost_analysis()`` counts while
    bodies once, undercounting scanned programs by their trip counts; its
    aggregates are kept as ``xla_*`` reference fields.
    """
    from .hlo_analyzer import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    hc = analyze_hlo(hlo, default_group=default_group or chips)
    flops = hc.flops
    bytes_accessed = hc.bytes_accessed
    coll = parse_collectives(hlo, default_group or chips)
    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    terms = roofline_terms(flops, bytes_accessed,
                           hc.collective_wire_bytes, chips, model_flops)
    return {
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": bytes_accessed,
        "collective_link_bytes": hc.collective_wire_bytes,
        "collective_counts": hc.collective_counts,
        "collective_tensor_bytes": coll.tensor_bytes,
        "num_whiles": hc.num_whiles,
        "max_trip_count": hc.max_trip_count,
        "xla_flops_per_chip": xla_flops,
        "xla_bytes_per_chip": xla_bytes,
        "static_collective_counts": coll.counts,
        "memory": memory,
        **terms,
    }
