"""Loop-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
ignoring ``known_trip_count`` — for scan-structured programs (layer scans,
microbatch accumulation, token-chunked MoE, SSM chunk scans) that
undercounts FLOPs/bytes/collective traffic by the trip count.  This module
parses the optimized HLO text into its computation graph and rolls costs up
through call sites with multipliers:

    while(... body=%B) with backend_config known_trip_count n  ->  n × B
    fusion/call/conditional/reduce to_apply                    ->  1 × callee

Per instruction:
* flops: ``dot`` = 2 · |output| · Π(contracted lhs dims); rough elementwise
  count for large fusions is intentionally ignored (MXU roofline = dots).
* bytes: Σ operand sizes + output size (same definition XLA uses, so the
  aggregate is comparable to ``cost_analysis()['bytes accessed']``).
* collectives: per-op wire bytes with ring factors (shared with
  :mod:`.analysis`).

The result is the corrected input for the §Roofline terms.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALLEE = re.compile(
    r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_BRANCHES = re.compile(
    r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*"?n"?\s*[:=]\s*"?(\d+)"?')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_info(shape_str: str) -> Tuple[int, List[int]]:
    """Returns (bytes, dims-of-first-array)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for dtype, dims_s in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    bytes_: float = 0.0
    coll_wire: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    # (callee, multiplier, is_fusion) triples — fusion callees contribute
    # flops/collectives but NOT bytes (the call-site IO stands in for the
    # fused region's memory traffic)
    calls: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)


# ops that move no data of their own (metadata/aliasing/control)
_ZERO_BYTE_OPS = frozenset({
    "tuple", "get-tuple-element", "parameter", "constant", "after-all",
    "bitcast", "bitcast-convert", "opt-barrier", "partition-id",
    "replica-id", "rng-get-and-update-state", "domain",
})
# ops whose traffic is the *slice*, not the full operand
_SLICE_OPS = frozenset({"dynamic-slice", "gather", "slice"})
_UPDATE_OPS = frozenset({"dynamic-update-slice", "scatter"})


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_wire_bytes: float
    collective_counts: Dict[str, int]
    num_whiles: int
    max_trip_count: int


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def analyze_hlo(text: str, *, default_group: int = 1,
                default_trip: int = 1) -> HloCost:
    comps: Dict[str, _Comp] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    shapes: Dict[str, str] = {}
    num_whiles = 0
    max_trip = 1

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                current = m.group(1)
                comps[current] = _Comp()
                if line.lstrip().startswith("ENTRY"):
                    entry = current
                shapes = {}
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        shapes[name] = shape_str
        c = comps[current]
        out_bytes, out_dims = _shape_info(shape_str)

        # operand bytes (looked up from earlier defs in this computation)
        opnd_names = []
        opnd_bytes = 0
        paren = line[line.index("(", line.index(op)) + 1:]
        for om in re.finditer(r"%([\w\.\-]+)", paren.split(")")[0]):
            opnd_names.append(om.group(1))
            s = shapes.get(om.group(1))
            if s:
                opnd_bytes += _shape_info(s)[0]

        # per-op memory-traffic model
        if op in _ZERO_BYTE_OPS or op in ("while", "conditional", "call",
                                          "fusion"):
            pass  # callees accounted separately; plumbing is free
        elif op in _SLICE_OPS:
            c.bytes_ += 2 * out_bytes
        elif op in _UPDATE_OPS:
            upd = (shapes.get(opnd_names[1]) if len(opnd_names) > 1 else None)
            ub = _shape_info(upd)[0] if upd else out_bytes
            c.bytes_ += 2 * ub
        else:
            c.bytes_ += out_bytes + opnd_bytes

        if op == "dot":
            cm = _CONTRACT.search(line)
            contracted = 1
            # lhs is the first parsed operand (newer XLA prints inline
            # operand shapes, so "(%name" no longer appears in the text)
            if cm and opnd_names and opnd_names[0] in shapes:
                lhs_dims = _shape_info(shapes[opnd_names[0]])[1]
                for d in cm.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        contracted *= lhs_dims[int(d)]
            n_out = 1
            for d in out_dims:
                n_out *= d
            c.flops += 2.0 * n_out * contracted
        elif op in ("convolution",):
            # not used by this framework's models; count as dot-free
            pass

        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is not None and not op.endswith("-done"):
            g = _group_size(line, default_group)
            size = out_bytes if base == "all-gather" else \
                max(out_bytes, opnd_bytes)
            if base == "all-reduce":
                wire = 2 * size * (g - 1) / max(g, 1)
            elif base in ("all-gather", "reduce-scatter", "all-to-all"):
                wire = size * (g - 1) / max(g, 1)
            else:
                wire = size
            c.coll_wire += wire
            c.coll_counts[base] += 1

        # call edges
        if op == "while":
            tm = _TRIP.search(line)
            trips = int(tm.group(1)) if tm else default_trip
            num_whiles += 1
            max_trip = max(max_trip, trips)
            cm_ = _CALLEE.search(line)
            if cm_:
                c.calls.append((cm_.group(1), float(trips), False))
            # condition computation: negligible, skipped
        elif op == "conditional":
            bm = _COND_BRANCHES.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        c.calls.append((b, 1.0, False))
        else:
            cm_ = _CALLEE.search(line)
            if cm_:
                c.calls.append((cm_.group(1), 1.0, op == "fusion"))

    if entry is None:
        entry = next(iter(comps), None)

    def rollup(skip_fusion_edges: bool) -> Dict[str, float]:
        # iterate to fixpoint (the call graph is a DAG of small depth)
        mults = {k: 0.0 for k in comps}
        if entry is None:
            return mults
        mults[entry] = 1.0
        for _ in range(64):
            new = {k: 0.0 for k in comps}
            new[entry] = 1.0
            for name, comp in comps.items():
                m = mults.get(name, 0.0)
                if m == 0.0:
                    continue
                for callee, cm_, is_fusion in comp.calls:
                    if callee in new and not (skip_fusion_edges and
                                              is_fusion):
                        new[callee] += m * cm_
            if all(abs(new[k] - mults[k]) <= 1e-9 for k in comps):
                return new
            mults = new
        return mults

    # flops/collectives and bytes both roll through fusion bodies: the
    # site charges nothing, internal ops use the slice-aware model (an
    # elementwise chain inside a fusion is over-counted ~2x, but big-tensor
    # traffic — weight reads, slices of stacked scan params — is right).
    mults = rollup(skip_fusion_edges=False)        # flops / collectives
    mults_b = mults                                # bytes

    flops = sum(c.flops * mults.get(n, 0.0) for n, c in comps.items())
    bytes_ = sum(c.bytes_ * mults_b.get(n, 0.0) for n, c in comps.items())
    wire = sum(c.coll_wire * mults.get(n, 0.0) for n, c in comps.items())
    counts = {k: 0 for k in _COLLECTIVES}
    for n, c in comps.items():
        for k in _COLLECTIVES:
            counts[k] += int(round(c.coll_counts[k] * mults.get(n, 0.0)))
    return HloCost(flops=flops, bytes_accessed=bytes_,
                   collective_wire_bytes=wire, collective_counts=counts,
                   num_whiles=num_whiles, max_trip_count=max_trip)
