"""Render EXPERIMENTS.md tables from a dry-run results directory.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun_corrected
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(dirpath: str) -> List[Dict]:
    out = []
    for f in sorted(os.listdir(dirpath)):
        if f.endswith(".json") and f != "summary.json":
            out.append(json.load(open(os.path.join(dirpath, f))))
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | params | per-chip args | temp | "
           "collectives (AR/AG/RS/A2A/CP) | compile |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        cc = r.get("collective_counts", {})
        coll = "/".join(str(cc.get(k, 0)) for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        mem = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('param_count', 0) / 1e9:.2f}B | "
            f"{fmt_bytes(mem.get('argument_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_bytes'))} | {coll} | "
            f"{r.get('compile_seconds', 0):.0f}s |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bound | MODEL/HLO flops | what would move the bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["shape"] == "explore_step":
            continue
        frac = r.get("useful_flops_frac")
        frac_s = f"{frac:.2f}" if frac else "-"
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bound']}** | {frac_s} | {hint} |")
    return "\n".join(out)


def _hint(r: Dict) -> str:
    b = r["bound"]
    if b == "memory":
        return ("fuse/remat less, shard activations (SP), bf16 "
                "intermediates")
    if b == "collective":
        return ("overlap collectives w/ compute, int8 grad compression, "
                "reduce resharding")
    return "larger per-chip tiles, higher MXU utilization"


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_corrected"
    rows = load(d)
    print("## Dry-run records\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline (multi-pod, 2x16x16 = 512 chips)\n")
    print(roofline_table(rows, "2x16x16"))


if __name__ == "__main__":
    main()
