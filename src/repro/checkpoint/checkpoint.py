"""Fault-tolerant checkpointing: atomic, content-verified, async,
topology-independent.

Layout: ``<dir>/step_<n>/`` holding one ``arrays.npz`` (flattened pytree,
path-keyed) + ``manifest.json`` (shapes, dtypes, per-array SHA256, pytree
structure).  Writes go to ``step_<n>.tmp`` and are renamed only after fsync
— a crashed writer can never corrupt the latest complete checkpoint.

Restore is *reshard-on-load*: arrays are materialized host-side and
``device_put`` with whatever NamedSharding the (possibly different) mesh
provides — a checkpoint from a 512-chip run restores onto 256 or 8 chips
unchanged, which is the substrate for elastic scaling
(:mod:`repro.runtime.elastic`).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)
    manifest = {
        "step": step,
        "extra": extra or {},
        "arrays": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype),
                "sha256": hashlib.sha256(v.tobytes()).hexdigest()}
            for k, v in arrays.items()
        },
        # restore() rebuilds structure from a template, so only a repr of
        # the treedef is stored (as a human-readable integrity aid)
        "treedef_repr": str(jax.tree_util.tree_structure(tree))[:2000],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, template: Any, *, step: Optional[int] = None,
    shardings: Any = None, verify: bool = True,
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template``; optionally device_put
    with per-leaf ``shardings`` (a congruent pytree of NamedSharding —
    any topology).  Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    if verify:
        for k, meta in manifest["arrays"].items():
            h = hashlib.sha256(data[k].tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {k} at step {step}")

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (pathk, leaf), shard in zip(flat_t, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in pathk)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, step, manifest["extra"]


class AsyncCheckpointer:
    """Background-thread checkpointing: the train loop hands off host
    copies and keeps stepping; ``wait()`` joins before exit.  Keeps the
    last ``keep`` checkpoints."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
