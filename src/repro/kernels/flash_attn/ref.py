"""Pure-jnp oracle for flash attention: materialized-scores softmax
attention with GQA, causal masking and per-row KV length masking."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def _safe_softmax(s: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(jnp.broadcast_to(mask, s.shape), p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.where(denom > 0, p / jnp.maximum(denom, 1e-30), 0.0)


def attention_ref(
    q: jnp.ndarray,        # (B, Hq, Sq, D)
    k: jnp.ndarray,        # (B, Hkv, Skv, D)
    v: jnp.ndarray,        # (B, Hkv, Skv, D)
    kv_len: jnp.ndarray | None = None,   # (B,) int32
    *,
    causal: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    mask = jnp.ones((B, 1, Sq, Skv), bool)
    if causal:
        iq = jnp.arange(Sq)[:, None]
        jk = jnp.arange(Skv)[None, :]
        mask &= (jk <= iq)[None, None]
    if kv_len is not None:
        mask &= (jnp.arange(Skv)[None, None, None, :]
                 < kv_len[:, None, None, None])
    s = jnp.where(mask, s, -1e30)
    p = _safe_softmax(s, mask)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
