"""Public flash-attention entry point.

* pads sequence lengths to tile multiples (padding keys are masked via
  ``kv_len``; padding queries are sliced off),
* exposes a ``custom_vjp`` so the kernel is usable inside ``train_step``:
  forward = Pallas kernel, backward = XLA recompute of the standard
  attention gradient (flash-style backward kernel is future work; the
  recompute backward preserves O(S) memory on the forward pass, which is
  where the prefill roofline lives),
* ``impl='xla'`` falls back to the reference for debugging/CPU perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7)
)
def _flash(q, k, v, kv_len, causal, block_q, block_k, interpret):
    return _flash_fwd_impl(q, k, v, kv_len, causal, block_q, block_k,
                           interpret)


def _flash_fwd_impl(q, k, v, kv_len, causal, block_q, block_k, interpret):
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    Sq_p = _round_up(Sq, min(block_q, Sq))
    Skv_p = _round_up(Skv, min(block_k, Skv))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    out = flash_attention_pallas(
        qp, kp, vp, jnp.minimum(kv_len, Skv),
        causal=causal, block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :Sq]


def _flash_fwd(q, k, v, kv_len, causal, block_q, block_k, interpret):
    out = _flash_fwd_impl(q, k, v, kv_len, causal, block_q, block_k,
                          interpret)
    return out, (q, k, v, kv_len)


def _flash_bwd(causal, block_q, block_k, interpret, res, g):
    q, k, v, kv_len = res

    def f(q, k, v):
        return attention_ref(q, k, v, kv_len, causal=causal)

    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_len: jnp.ndarray | None = None,
    *,
    causal: bool = True,
    impl: str = "pallas",
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Softmax attention, (B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    ``kv_len`` (B,) masks trailing cache slots (serving); defaults to full.
    """
    if kv_len is None:
        kv_len = jnp.full((q.shape[0],), k.shape[2], jnp.int32)
    if impl == "xla":
        return attention_ref(q, k, v, kv_len, causal=causal)
    if impl != "pallas":
        raise ValueError(f"unknown attention impl {impl!r}")
    return _flash(q, k, v, kv_len, causal, block_q, block_k, interpret)
