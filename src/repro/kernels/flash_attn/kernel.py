"""Flash attention forward kernel (Pallas, TPU) with GQA and causal masking.

Online-softmax tiling: grid ``(batch, q_heads, Sq/bq, Skv/bk)`` with the KV
dimension innermost; running max / normalizer / accumulator live in VMEM
scratch across KV tiles, so the ``(Sq, Skv)`` score matrix never exists in
HBM.  GQA is folded into the BlockSpec index map (``kv_head = q_head //
group``) — no K/V replication in memory.  Fully-masked causal tiles are
skipped on the VPU/MXU via ``pl.when``.

Targets the MXU with (128, 128) score tiles; head_dim rides along lanes.
Validated in interpret mode against :mod:`.ref`; use ``ops.flash_attention``
for the public (custom-vjp, padding-aware) entry point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int):
    i = pl.program_id(2)          # q tile
    j = pl.program_id(3)          # kv tile (innermost)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal: skip tiles strictly above the diagonal band.
    q_start = i * block_q
    k_start = j * block_k
    needed = True
    if causal:
        needed = k_start < q_start + block_q

    @pl.when(needed)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (bq, bk)
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jk < len_ref[0]                                # kv validity
        if causal:
            mask &= jk <= iq
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...][:, :1]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        # rows with no valid key yet: keep p exactly zero
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_new = l_ref[...][:, :1] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,        # (B, Hq, Sq, D)
    k: jnp.ndarray,        # (B, Hkv, Skv, D)
    v: jnp.ndarray,        # (B, Hkv, Skv, D)
    kv_len: jnp.ndarray,   # (B,) int32 — valid KV prefix per batch row
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires q_heads % kv_heads == 0"
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (
        "ops.py pads sequence lengths to block multiples"
    )
    grid = (B, Hq, Sq // block_q, Skv // block_k)
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, kv_len.astype(jnp.int32))
