"""Memory-light attention in pure JAX: flash-style chunking with a custom
VJP whose backward pass is *also* chunked.

Motivation (EXPERIMENTS.md §Perf): the XLA einsum attention materializes
the (B, H, Sq, Skv) score tensor in f32 — at 4k-32k sequence lengths that
single tensor dominates the dry-run memory roofline term for every
full-attention cell.  This implementation never materializes more than one
(block_q × Skv) panel per step:

* forward: ``lax.scan`` over query blocks; inside, one pass over K/V with
  running (max, sumexp, acc) — saves only O and the logsumexp rows,
* backward: recomputes score panels per query block from (q, k, L) and
  accumulates dq/dk/dv — O(S·d) residuals instead of O(S²).

On TPU the Pallas kernel (kernel.py) is the forward of choice; this module
is the portable/bwd-complete path the train step uses, and doubles as the
Pallas kernel's memory-behavior twin at the HLO level.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_attention"]

_NEG = -1e30


def _blockwise_fwd(q, k, v, kv_len, causal, block_q, block_k, scale):
    """Returns (out (B,H,Sq,D), lse (B,H,Sq))."""
    B, H, Sq, D = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[2]
    nq = Sq // block_q
    nk = Skv // block_k

    jk = jnp.arange(Skv)
    kv_mask = jk[None, :] < kv_len[:, None]              # (B, Skv)

    def one_q_block(carry, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * block_q, block_q, 2)
        q_blk = q_blk.astype(jnp.float32) * scale
        iq = qi * block_q + jnp.arange(block_q)

        def one_k_block(state, ki):
            m, l, acc = state
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * block_k,
                                                 block_k, 2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * block_k,
                                                 block_k, 2)
            mask_blk = jax.lax.dynamic_slice_in_dim(kv_mask, ki * block_k,
                                                    block_k, 1)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk,
                           k_blk.astype(jnp.float32))
            msk = mask_blk[:, None, None, :]
            if causal:
                jkk = ki * block_k + jnp.arange(block_k)
                msk = msk & (jkk[None, None, None, :]
                             <= iq[None, None, :, None])
            s = jnp.where(msk, s, _NEG)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, block_q), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(one_k_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = jnp.where(l[..., None] > 0,
                        acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return carry, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(one_q_block, (), jnp.arange(nq))
    # outs: (nq, B, H, bq, Dv) -> (B, H, Sq, Dv)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, Sq, Dv)
    lse = jnp.moveaxis(lses, 0, 2).reshape(B, H, Sq)
    return out, lse


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _chunked(q, k, v, kv_len, causal, block_q, block_k):
    out, _ = _fwd_padded(q, k, v, kv_len, causal, block_q, block_k)
    return out


def _fwd_padded(q, k, v, kv_len, causal, block_q, block_k):
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    scale = 1.0 / (D ** 0.5)
    out, lse = _blockwise_fwd(
        _pad_to(q, Sq_p, 2), _pad_to(k, Skv_p, 2), _pad_to(v, Skv_p, 2),
        jnp.minimum(kv_len, Skv), causal, bq, bk, scale)
    return out[:, :, :Sq], lse[:, :, :Sq]


def _chunked_fwd(q, k, v, kv_len, causal, block_q, block_k):
    out, lse = _fwd_padded(q, k, v, kv_len, causal, block_q, block_k)
    return out, (q, k, v, kv_len, out, lse)


def _chunked_bwd(causal, block_q, block_k, res, g):
    q, k, v, kv_len, out, lse = res
    B, H, Sq, D = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    scale = 1.0 / (D ** 0.5)

    qp = _pad_to(q, Sq_p, 2).astype(jnp.float32)
    kp = _pad_to(k, Skv_p, 2).astype(jnp.float32)
    vp = _pad_to(v, Skv_p, 2).astype(jnp.float32)
    gp = _pad_to(g, Sq_p, 2).astype(jnp.float32)
    op = _pad_to(out, Sq_p, 2).astype(jnp.float32)
    lsep = _pad_to(lse, Sq_p, 2)
    # rows beyond Sq: force p = 0 via lse = +inf surrogate
    if Sq_p != Sq:
        pad_rows = jnp.arange(Sq_p) >= Sq
        lsep = jnp.where(pad_rows[None, None, :], 1e30, lsep)

    delta = (gp * op).sum(-1)                            # (B,H,Sq_p)
    jk = jnp.arange(Skv_p)
    kv_mask = jk[None, :] < jnp.minimum(kv_len, Skv)[:, None]

    nq, nk = Sq_p // bq, Skv_p // bk

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        sl = lambda x, i=qi: jax.lax.dynamic_slice_in_dim(x, i * bq, bq, 2)
        q_blk, g_blk = sl(qp) * scale, sl(gp)
        lse_blk, d_blk = sl(lsep[..., None])[..., 0], sl(
            delta[..., None])[..., 0]
        iq = qi * bq + jnp.arange(bq)

        def k_block(state, ki):
            dq_blk, dk_acc, dv_acc = state
            ksl = lambda x: jax.lax.dynamic_slice_in_dim(x, ki * bk, bk, 2)
            k_blk, v_blk = ksl(kp), ksl(vp)
            mask_blk = jax.lax.dynamic_slice_in_dim(kv_mask, ki * bk, bk, 1)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk)
            msk = mask_blk[:, None, None, :]
            if causal:
                jkk = ki * bk + jnp.arange(bk)
                msk = msk & (jkk[None, None, None, :]
                             <= iq[None, None, :, None])
            p = jnp.where(msk, jnp.exp(s - lse_blk[..., None]), 0.0)
            dp = jnp.einsum("bhqd,bhkd->bhqk", g_blk, v_blk)
            ds = p * (dp - d_blk[..., None])             # (B,H,bq,bk)
            dq_blk = dq_blk + jnp.einsum("bhqk,bhkd->bhqd", ds, k_blk)
            dk_upd = jnp.einsum("bhqk,bhqd->bhkd", ds, q_blk)
            dv_upd = jnp.einsum("bhqk,bhqd->bhkd", p, g_blk)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(
                    dk_acc, ki * bk, bk, 2) + dk_upd, ki * bk, 2)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(
                    dv_acc, ki * bk, bk, 2) + dv_upd, ki * bk, 2)
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, H, bq, D), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            k_block, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_blk * scale

    dk0 = jnp.zeros((B, H, Skv_p, D), jnp.float32)
    dv0 = jnp.zeros((B, H, Skv_p, Dv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 2).reshape(B, H, Sq_p, D)[:, :, :Sq]
    return (dq.astype(q.dtype), dk[:, :, :Skv].astype(k.dtype),
            dv[:, :, :Skv].astype(v.dtype), None)


_chunked.defvjp(_chunked_fwd, _chunked_bwd)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    kv_len: Optional[jnp.ndarray] = None, *, causal: bool = True,
    block_q: int = 512, block_k: int = 1024,
) -> jnp.ndarray:
    """(B,Hq,Sq,D)x(B,Hkv,Skv,D) -> (B,Hq,Sq,D); GQA via head repeat at the
    einsum level (no K/V copy: repeat is folded by XLA into the einsum)."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    if kv_len is None:
        kv_len = jnp.full((B,), k.shape[2], jnp.int32)
    if Hq != Hkv:
        group = Hq // Hkv
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    return _chunked(q, k, v, kv_len, causal, block_q, block_k)
