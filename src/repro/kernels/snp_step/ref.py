"""Pure-jnp oracle for the fused SNP transition kernel.

Delegates to :mod:`repro.core.semantics` — the reference semantics used by
the paper-reproduction tests — so the kernel is validated against exactly
the math the rest of the framework runs on.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.matrix import CompiledSNP
from repro.core.semantics import next_configs

__all__ = ["snp_step_ref"]


def snp_step_ref(configs: jnp.ndarray, comp: CompiledSNP, max_branches: int):
    """Returns (successors (B,T,m) i32, valid (B,T) bool, emissions (B,T) i32,
    overflow (B,) bool)."""
    out = next_configs(configs, comp, max_branches)
    return out.configs, out.valid, out.emissions, out.overflow
