"""Public jit'd wrapper around the fused SNP transition kernel.

Handles everything the raw kernel assumes away: the cheap O(B·n) branch
bookkeeping (applicability, ranks, radix strides — computed with the
reference semantics), padding every dimension to block multiples (padding
rules never fire: app=0, M rows=0), and unpadding/masking the results.

On CPU the kernel runs in interpret mode; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.matrix import CompiledSNP
from repro.core.semantics import branch_info

from .kernel import snp_step_pallas

__all__ = ["snp_step"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "block_n",
                     "interpret"),
)
def snp_step(
    configs: jnp.ndarray,   # (B, m) int32
    comp: CompiledSNP,
    *,
    max_branches: int,
    block_b: int = 8,
    block_t: int = 128,
    block_n: int = 512,
    interpret: bool = True,
):
    """Fused successor expansion: returns (successors (B,T,m) int32,
    valid (B,T) bool, emissions (B,T) int32, overflow (B,) bool).

    Bit-identical to :func:`repro.kernels.snp_step.ref.snp_step_ref` for all
    spike counts < 2^24 (f32-exact integer range).
    """
    B, m = configs.shape
    n = comp.num_rules
    T = max_branches

    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)
    block_n = min(block_n, _round_up(n, 128))

    info = branch_info(configs, comp)
    stride = jnp.minimum(info.stride, 2.0 ** 30).astype(jnp.int32)
    # clamp choices>=1 so the kernel's % never sees 0 (already >=1 by defn)

    Bp, Tp, Np = (_round_up(B, block_b), _round_up(T, block_t),
                  _round_up(n, block_n))

    def pad(x, rows=None, cols=None, value=0):
        pads = [(0, 0)] * x.ndim
        if rows is not None:
            pads[0] = (0, rows - x.shape[0])
        if cols is not None:
            pads[-1] = (0, cols - x.shape[-1])
        return jnp.pad(x, pads, constant_values=value)

    out, valid, emis = snp_step_pallas(
        pad(configs, rows=Bp),
        pad(pad(info.rank, cols=Np, value=-1), rows=Bp),
        pad(pad(info.app, cols=Np), rows=Bp),
        # padded configs: stride 1 / choices 1 / psi 0 -> no valid branches
        pad(stride, rows=Bp, value=1),
        pad(info.choices, rows=Bp, value=1),
        pad(info.psi, rows=Bp),
        pad(comp.neuron_onehot, rows=Np),           # (n, m) pad rules
        pad(comp.M, rows=Np),
        pad(comp.env_produce, rows=Np),
        max_branches=Tp,
        block_b=block_b, block_t=block_t, block_n=block_n,
        interpret=interpret,
    )
    out = out[:B, :T]
    valid = valid[:B, :T] & info.alive[:, None]
    emis = emis[:B, :T]
    overflow = info.psi > float(T)
    return out, valid, emis, overflow
