"""Public jit'd wrappers around the fused dense SNP transition kernel.

Handles everything the raw kernel assumes away: the cheap O(B·n) branch
bookkeeping (applicability, ranks, radix strides — computed with the
reference semantics), padding every dimension to block multiples (padding
rules never fire: app=0, M rows=0), and unpadding/masking the results.

:func:`snp_step` is the single-device step on a
:class:`~repro.core.matrix.CompiledSNP`; :func:`snp_step_dense_shard`
steps one neuron shard of a :class:`~repro.core.plan.ShardedCompiled`
through the same kernel body's halo form (``C' = C + halo·H_adj +
S·M_local`` — DESIGN.md §3 "Kernel lowering"), with the bookkeeping and
the halo exchange owned by ``explore_distributed``'s sharded step.

On CPU the kernel runs in interpret mode; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.matrix import CompiledSNP, is_delayed
from repro.core.plan import KernelConfig
from repro.core.semantics import (branch_info, delayed_branch_info,
                                  delayed_weight_matrix, split_state)

from .kernel import snp_step_pallas

__all__ = ["snp_step", "snp_step_dense_shard"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _resolve_blocks(kernel: Optional[KernelConfig], block_b, block_t,
                    block_n):
    """The effective dense block shape: explicit per-axis kwarg >
    ``kernel`` config field > :meth:`KernelConfig.dense_default`.  Both
    wrappers resolve through here so precedence can't diverge."""
    base = KernelConfig.dense_default() if kernel is None else \
        KernelConfig.dense_default().merged(
            block_b=kernel.block_b, block_t=kernel.block_t,
            block_n=kernel.block_n)
    cfg = base.merged(block_b=block_b, block_t=block_t, block_n=block_n)
    return cfg.block_b, cfg.block_t, cfg.block_n


def _pad(x, rows=None, cols=None, value=0):
    """Pad the leading (batch/rule) and/or trailing axis to a block
    multiple — shared by both wrappers so padding semantics can't
    diverge."""
    pads = [(0, 0)] * x.ndim
    if rows is not None:
        pads[0] = (0, rows - x.shape[0])
    if cols is not None:
        pads[-1] = (0, cols - x.shape[-1])
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "block_n",
                     "kernel", "interpret"),
)
def snp_step(
    configs: jnp.ndarray,   # (B, m) int32
    comp: CompiledSNP,
    *,
    max_branches: int,
    block_b: Optional[int] = None,
    block_t: Optional[int] = None,
    block_n: Optional[int] = None,
    kernel: Optional[KernelConfig] = None,
    interpret: bool = True,
):
    """Fused successor expansion: returns (successors (B,T,m) int32,
    valid (B,T) bool, emissions (B,T) int32, overflow (B,) bool).

    The block shape comes from ``kernel`` (a hashable
    :class:`~repro.core.plan.KernelConfig`, usually carried by a
    ``SystemPlan``), overridable per axis with the explicit kwargs;
    unset axes fall back to :meth:`KernelConfig.dense_default`.

    Bit-identical to :func:`repro.kernels.snp_step.ref.snp_step_ref` for all
    spike counts < 2^24 (f32-exact integer range).  A delayed ``comp``
    (``semantics="delays"``; 3m-wide state rows) routes through the
    kernel's delay stage and returns ``(B, T, 3m)`` successors,
    bit-identical to :func:`repro.core.semantics.delayed_next_configs`.
    """
    B = configs.shape[0]
    n = comp.num_rules
    m = comp.num_neurons
    T = max_branches
    delayed = is_delayed(comp)

    block_b, block_t, block_n = _resolve_blocks(
        kernel, block_b, block_t, block_n)
    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)
    block_n = min(block_n, _round_up(n, 128))

    if delayed:
        info = delayed_branch_info(configs, comp)
        spikes, cd, pd = split_state(configs)
    else:
        info = branch_info(configs, comp)
        spikes, cd, pd = configs, None, None
    stride = jnp.minimum(info.stride, 2.0 ** 30).astype(jnp.int32)
    # clamp choices>=1 so the kernel's % never sees 0 (already >=1 by defn)

    Bp, Tp, Np = (_round_up(B, block_b), _round_up(T, block_t),
                  _round_up(n, block_n))

    if delayed:
        weights = _pad(delayed_weight_matrix(comp), rows=Np)   # (Np, 4m)
        extra = dict(
            cd=_pad(cd, rows=Bp),
            pd=_pad(pd, rows=Bp),
            adj=comp.adjacency,
            # all-zero one-hot when the system has no output neuron
            # (out_neuron == m) — emissions then stay 0, matching the
            # reference's zero-padded gather.
            outoh=(jnp.arange(m) == comp.out_neuron).astype(jnp.int32),
        )
    else:
        weights = _pad(comp.M, rows=Np)
        extra = {}

    out, valid, emis = snp_step_pallas(
        _pad(spikes, rows=Bp),
        _pad(_pad(info.rank, cols=Np, value=-1), rows=Bp),
        _pad(_pad(info.app, cols=Np), rows=Bp),
        # padded configs: stride 1 / choices 1 / psi 0 -> no valid branches
        _pad(stride, rows=Bp, value=1),
        _pad(info.choices, rows=Bp, value=1),
        _pad(info.psi, rows=Bp),
        _pad(comp.neuron_onehot, rows=Np),          # (n, m) pad rules
        weights,
        _pad(comp.env_produce, rows=Np),
        max_branches=Tp,
        block_b=block_b, block_t=block_t, block_n=block_n,
        interpret=interpret,
        **extra,
    )
    out = out[:B, :T]
    valid = valid[:B, :T] & info.alive[:, None]
    emis = emis[:B, :T]
    overflow = info.psi > float(T)
    return out, valid, emis, overflow


def snp_step_dense_shard(
    configs: jnp.ndarray,   # (B, mloc) int32 — local frontier slices
    rank: jnp.ndarray,      # (B, nloc) int32 — local-rule ranks
    app: jnp.ndarray,       # (B, nloc) bool — local-rule applicability
    stride: jnp.ndarray,    # (B, mloc) f32 — cross-shard-combined strides
    choices: jnp.ndarray,   # (B, mloc) int32
    psi: jnp.ndarray,       # (B,) f32 — replicated global Ψ
    onehot: jnp.ndarray,    # (nloc, mloc) int8 — rule→local-neuron map
    M_local: jnp.ndarray,   # (nloc, mloc) int32 — local columns of M_Π
    hadj: jnp.ndarray,      # (H, mloc) int8 — halo 0/1 in-adjacency
    halo: jnp.ndarray,      # (B, T, H) int32 — received remote produce
    *,
    max_branches: int,
    block_b: Optional[int] = None,
    block_t: Optional[int] = None,
    block_n: Optional[int] = None,
    kernel: Optional[KernelConfig] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """One shard's candidate slices ``(B, T, mloc)`` through the fused
    dense kernel (``C' = C + halo·H_adj + S·M_local`` — kernel.py module
    docstring).  Bookkeeping and the halo exchange belong to the caller
    (``explore_distributed``'s sharded step); this wrapper pads to block
    multiples and clamps the saturating f32 strides into the kernel's
    int32 decode.  Traceable inside ``shard_map``."""
    B, m = configs.shape
    n = rank.shape[1]
    T = max_branches
    block_b, block_t, block_n = _resolve_blocks(
        kernel, block_b, block_t, block_n)
    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)
    block_n = min(block_n, _round_up(n, 128))
    Bp, Tp, Np = (_round_up(B, block_b), _round_up(T, block_t),
                  _round_up(n, block_n))

    halo_p = jnp.pad(halo, [(0, Bp - B), (0, Tp - T), (0, 0)])
    out, _, _ = snp_step_pallas(
        _pad(configs, rows=Bp),
        _pad(_pad(rank, cols=Np, value=-1), rows=Bp),
        _pad(_pad(app, cols=Np), rows=Bp),
        _pad(jnp.minimum(stride, 2.0 ** 30).astype(jnp.int32),
             rows=Bp, value=1),
        _pad(choices, rows=Bp, value=1),
        _pad(psi, rows=Bp),
        _pad(onehot, rows=Np),
        _pad(M_local, rows=Np),
        jnp.zeros((Np,), jnp.int32),    # shard emissions: driver's job
        halo=halo_p,
        hadj=hadj,
        max_branches=Tp,
        block_b=block_b, block_t=block_t, block_n=block_n,
        interpret=interpret,
    )
    return out[:B, :T]
