"""Fused *sparse* SNP transition kernel (Pallas, TPU).

The dense kernel (:mod:`.kernel`) streams the ``(n, m)`` matrix through the
MXU; this kernel never sees an ``O(n·m)`` operand.  For a tile of
configurations and branch indices it computes, entirely in VMEM,

    digits[b, t, μ]  = (t // stride[b, μ]) % choices[b, μ]       (VPU, f32 —
                       exact for T < 2^23, see semantics._decode_digits)
    packed[b, t, μ]  = tab[b, μ, digits[b, t, μ]]                (unrolled
                       select over the R rule slots — no dynamic gather)
    ΔC[b, t, j]      = Σ_{k < K_in} produce[b, t, in_idx[j, k]]
                       - consume[b, t, j]                        (gather/sum)
    C'[b, t, :]      = C[b, :] + ΔC[b, t, :]

where ``tab`` is the per-config packed rule table (``produce | consume <<
16`` of the d-th applicable rule per neuron, 0 where none — built by the
ops wrapper via :func:`repro.core.semantics.packed_rule_table`,
``O(B·m·R)``) and ``in_idx`` is the ELL-packed synapse in-adjacency
(DESIGN.md §3).  The environment emission is the fired produce at the
output neuron.  Work per (b, t) is ``O(m·(1 + K_in))`` — proportional to
``nnz(M_Π)``, not ``n·m``.

**One body, every ``SystemPlan`` encoding** (DESIGN.md §3 "Kernel
lowering").  The ELL body above is parameterized by two pieces of encoding
metadata, both optional and both scatter-free:

* **COO segment-sum stage** (hybrid ELL+COO plans): the compiler sorts the
  tail by ``(dst, src)`` and records per-hub run offsets
  (``coo_bounds``) plus a neuron→hub map (``hub_slot``), so the tail
  contribution is a gather + inclusive ``cumsum`` + two static-shape
  gathers of run endpoints — never a scatter:

      contrib = produce_fired[coo_src]                 (gather, (bb,bt,Ec))
      cum0    = [0, cumsum(contrib)]                   (VPU)
      tail[h] = cum0[bounds[h+1]] - cum0[bounds[h]]    (gather, (bb,bt,Hn))
      ΔC[j]  += tail_pad[hub_slot[j]]                  (gather, (bb,bt,m))

* **halo extension** (neuron-axis-sharded plans): ``in_idx`` indexes the
  *extended* produce space ``[local (m) | halo (H) | zero]``; the halo
  produce values arrive as an extra kernel input (exchanged by
  ``explore_distributed``'s ``all_to_all`` *outside* the kernel — Pallas
  bodies hold no collectives), and the output-neuron index must already
  point at the extended zero slot.

Grid: ``(B/bb, T/bt)`` with the whole neuron axis resident per block; the
VMEM working set is ``O(bb·bt·(m + H + Ec))``, so the ops wrapper shrinks
``bb`` for very wide systems.  All arithmetic is int32 (exact).  TPU is
the compilation *target*; correctness is validated in ``interpret=True``
mode against :func:`repro.core.semantics.sparse_next_configs` (the
in-kernel gathers lower to Mosaic dynamic-gathers on real hardware —
revalidate bit-for-bit on a TPU before flipping ``interpret=False`` in
production, see ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

__all__ = ["snp_step_sparse_pallas"]


def _make_kernel(has_coo: bool, has_halo: bool, has_delay: bool = False):
    """ELL body specialized to the encoding metadata actually present
    (specialization keeps the ref list static for ``pallas_call``).
    ``has_delay`` selects the delayed-semantics tier; it is mutually
    exclusive with ``has_halo`` (plan.py refuses sharded delays)."""
    assert not (has_halo and has_delay)

    def kernel(*refs):
        it = iter(refs)
        c_ref = next(it)        # (bb, m)     i32 — configurations (spikes)
        stride_ref = next(it)   # (bb, m)     f32 — radix strides (may +inf)
        choices_ref = next(it)  # (bb, m)     i32 — per-neuron choices (>=1)
        psi_ref = next(it)      # (bb, 1)     f32 — number of valid branches
        tab_ref = next(it)      # (bb, m, R)  i32 — produce | consume << 16
        #                         (emit-now payload packed_e under delays)
        inidx_ref = next(it)    # (m, Kin)    i32 — extended-space indices
        outn_ref = next(it)     # (1,)        i32 — emission gather index
        if has_coo:
            coosrc_ref = next(it)   # (Ec,)    i32 — tail sources
            coob_ref = next(it)     # (Hn+1,)  i32 — per-hub run offsets
            hub_ref = next(it)      # (m,)     i32 — neuron -> hub slot
        if has_halo:
            halo_ref = next(it)     # (bb, bt, H) i32 — remote produce
        if has_delay:
            dtab_ref = next(it)     # (bb, m, R) i32 — produce | d << 16
            cd_ref = next(it)       # (bb, m)    i32 — countdowns
            pd_ref = next(it)       # (bb, m)    i32 — pending spikes
        out_ref = next(it)      # (bb, bt, m|3m) i32 — successor configs
        valid_ref = next(it)    # (bb, bt)    i32
        emis_ref = next(it)     # (bb, bt)    i32

        j = pl.program_id(1)   # branch-tile index
        bb, bt, _ = out_ref.shape
        m = c_ref.shape[-1]
        R = tab_ref.shape[2]
        Kin = inidx_ref.shape[1]

        # Branch ids for this tile; decode one mixed-radix digit per neuron
        # (f32 division, exact for T < 2^23 — semantics._decode_digits).
        t = j * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt, 1), 1)
        tf = t.astype(jnp.float32)
        stride = stride_ref[...].reshape(bb, 1, m)
        choices = choices_ref[...].reshape(bb, 1, m).astype(jnp.float32)
        q = jnp.floor(tf / stride)
        digits = (q - choices * jnp.floor(q / choices)).astype(jnp.int32)

        # Fired-rule actions: unrolled select over the R rule slots.
        tab = tab_ref[...]
        packed_f = jnp.zeros((bb, bt, m), jnp.int32)
        for d in range(R):  # static R, unrolled
            packed_f = jnp.where(
                digits == d, tab[:, :, d].reshape(bb, 1, m), packed_f)
        prod_f = packed_f & 0xFFFF   # emit-now produce under delays
        cons_f = packed_f >> 16

        if has_delay:
            # Second rank table: the fired *delayed* action (nonzero iff
            # the fired rule has d > 0, since d >= 1 sets bit 16+).
            dtab = dtab_ref[...]
            packed_d = jnp.zeros((bb, bt, m), jnp.int32)
            for d in range(R):  # static R, unrolled
                packed_d = jnp.where(
                    digits == d, dtab[:, :, d].reshape(bb, 1, m), packed_d)
            cd = cd_ref[...].reshape(bb, 1, m)
            pd = pd_ref[...].reshape(bb, 1, m)
            reopen = cd == 1
            # The vector riding the in-adjacency is the emit-now vector:
            # fired d=0 produce plus reopening neurons' pending spikes.
            prod_f = prod_f + jnp.where(reopen, pd, 0)

        # Extended produce space the in-adjacency indexes into: pure ELL is
        # [local | zero]; a shard adds the received halo produce between
        # them ([local | halo | zero]).  Padding entries always hit the
        # trailing zero, contributing nothing.
        parts = [prod_f]
        if has_halo:
            parts.append(halo_ref[...])
        parts.append(jnp.zeros((bb, bt, 1), jnp.int32))
        prod_ext = jnp.concatenate(parts, axis=-1)
        in_idx = inidx_ref[...]
        incoming = jnp.zeros((bb, bt, m), jnp.int32)
        for k in range(Kin):  # static K_in, unrolled
            incoming = incoming + jnp.take(prod_ext, in_idx[:, k], axis=-1)

        if has_coo:
            # COO segment-sum stage (module docstring): tail sources are
            # always local neurons, so gather from prod_ext's local prefix.
            contrib = jnp.take(prod_ext, coosrc_ref[...], axis=-1)
            cum0 = jnp.concatenate(
                [jnp.zeros((bb, bt, 1), jnp.int32),
                 jnp.cumsum(contrib, axis=-1)], axis=-1)
            bounds = coob_ref[...]
            tail = (jnp.take(cum0, bounds[1:], axis=-1)
                    - jnp.take(cum0, bounds[:-1], axis=-1))
            tail_pad = jnp.concatenate(
                [tail, jnp.zeros((bb, bt, 1), jnp.int32)], axis=-1)
            incoming = incoming + jnp.take(tail_pad, hub_ref[...], axis=-1)

        if not has_delay:
            out_ref[...] = c_ref[...].reshape(bb, 1, m) - cons_f + incoming
        else:
            # Closed-neuron algebra (core.semantics.sparse_delayed_
            # next_configs, bit-for-bit): reception gated on the post-
            # update countdown, pending landing consumed on reopen.
            fired_del = packed_d != 0
            prod_pend = packed_d & 0xFFFF
            d_f = packed_d >> 16
            cd_next = jnp.where(fired_del, d_f, jnp.maximum(cd - 1, 0))
            gate = cd_next == 0
            spikes = c_ref[...].reshape(bb, 1, m) - cons_f \
                + jnp.where(gate, incoming, 0)
            pd_next = jnp.where(fired_del, prod_pend,
                                jnp.where(reopen, 0, pd))
            out_ref[...] = jnp.concatenate(
                [spikes, cd_next, pd_next], axis=-1)
        tfv = t.reshape(1, bt).astype(jnp.float32)
        valid_ref[...] = (tfv < psi_ref[...]).astype(jnp.int32)
        emis_ref[...] = jnp.take(prod_ext, outn_ref[0], axis=-1)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "interpret"),
)
def snp_step_sparse_pallas(
    configs: jnp.ndarray,    # (B, m) int32, B % block_b == 0
    stride: jnp.ndarray,     # (B, m) float32 (saturating, may be +inf)
    choices: jnp.ndarray,    # (B, m) int32
    psi: jnp.ndarray,        # (B,) float32
    tab: jnp.ndarray,        # (B, m, R) int32 packed rule table
    in_idx: jnp.ndarray,     # (m, Kin) int32 — extended-space indices
    out_neuron: jnp.ndarray,  # () int32 — emission index (zero slot if none)
    coo_src: jnp.ndarray = None,     # (Ec,) int32 — hybrid tail sources
    coo_bounds: jnp.ndarray = None,  # (Hn+1,) int32 — per-hub run offsets
    hub_slot: jnp.ndarray = None,    # (m,) int32 — neuron -> hub slot
    halo: jnp.ndarray = None,        # (B, T, H) int32 — sharded halo produce
    dtab: jnp.ndarray = None,        # (B, m, R) int32 — delayed-action table
    cd: jnp.ndarray = None,          # (B, m) int32 — countdowns (delays)
    pd: jnp.ndarray = None,          # (B, m) int32 — pending spikes
    *,
    max_branches: int,
    block_b: int,
    block_t: int,
    interpret: bool = True,
):
    """Raw tiled kernel call.  Use :mod:`..sparse_ops` for the padded
    public API — the block shape is *required* here: the grid/tile choice
    belongs to the caller (ultimately a
    :class:`~repro.core.plan.KernelConfig` on the plan, DESIGN.md §3
    "Planner & autotuner"), not the kernel.  ``coo_*``/``hub_slot``
    select the COO segment-sum stage (hybrid plans), ``halo`` the
    extended-index shard stage — both default to the pure-ELL body.
    ``dtab``/``cd``/``pd`` select the delayed-semantics body (``tab``
    then carries the emit-now payload ``packed_e``) and the output widens
    to ``(B, T, 3m)`` state rows."""
    B, m = configs.shape
    R = tab.shape[2]
    Kin = in_idx.shape[1]
    T = max_branches
    assert B % block_b == 0 and T % block_t == 0, (
        "sparse_ops.py must pad shapes to block multiples"
    )
    has_coo = coo_src is not None and coo_src.shape[0] > 0
    has_halo = halo is not None
    has_delay = dtab is not None
    assert not (has_halo and has_delay), \
        "sharded delayed lowering is unsupported (plan.py refuses it)"
    out_m = 3 * m if has_delay else m
    grid = (B // block_b, T // block_t)

    in_specs = [
        pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((block_b, m, R), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((m, Kin), lambda i, j: (0, 0)),
        pl.BlockSpec((1,), lambda i, j: (0,)),
    ]
    operands = [
        configs.astype(jnp.int32),
        stride.astype(jnp.float32),
        choices.astype(jnp.int32),
        psi.reshape(B, 1).astype(jnp.float32),
        tab.astype(jnp.int32),
        in_idx.astype(jnp.int32),
        out_neuron.reshape(1).astype(jnp.int32),
    ]
    if has_coo:
        Ec, Hn = coo_src.shape[0], coo_bounds.shape[0] - 1
        in_specs += [
            pl.BlockSpec((Ec,), lambda i, j: (0,)),
            pl.BlockSpec((Hn + 1,), lambda i, j: (0,)),
            pl.BlockSpec((m,), lambda i, j: (0,)),
        ]
        operands += [coo_src.astype(jnp.int32),
                     coo_bounds.astype(jnp.int32),
                     hub_slot.astype(jnp.int32)]
    if has_halo:
        H = halo.shape[-1]
        in_specs.append(
            pl.BlockSpec((block_b, block_t, H), lambda i, j: (i, j, 0)))
        operands.append(halo.astype(jnp.int32))
    if has_delay:
        in_specs += [
            pl.BlockSpec((block_b, m, R), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        ]
        operands += [dtab.astype(jnp.int32), cd.astype(jnp.int32),
                     pd.astype(jnp.int32)]

    out, valid, emis = pl.pallas_call(
        _make_kernel(has_coo, has_halo, has_delay),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, block_t, out_m),
                         lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, out_m), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
        ],
        compiler_params=None if interpret else _CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(*operands)
    return out, valid.astype(bool), emis
