"""Fused *sparse* SNP transition kernel (Pallas, TPU).

The dense kernel (:mod:`.kernel`) streams the ``(n, m)`` matrix through the
MXU; this kernel never sees an ``O(n·m)`` operand.  For a tile of
configurations and branch indices it computes, entirely in VMEM,

    digits[b, t, μ]  = (t // stride[b, μ]) % choices[b, μ]       (VPU, f32 —
                       exact for T < 2^23, see semantics._decode_digits)
    packed[b, t, μ]  = tab[b, μ, digits[b, t, μ]]                (unrolled
                       select over the R rule slots — no dynamic gather)
    ΔC[b, t, j]      = Σ_{k < K_in} produce[b, t, in_idx[j, k]]
                       - consume[b, t, j]                        (gather/sum)
    C'[b, t, :]      = C[b, :] + ΔC[b, t, :]

where ``tab`` is the per-config packed rule table (``produce | consume <<
16`` of the d-th applicable rule per neuron, 0 where none — built by the
ops wrapper via :func:`repro.core.semantics.packed_rule_table`,
``O(B·m·R)``) and ``in_idx`` is the ELL-packed synapse in-adjacency
(DESIGN.md §3).  The environment emission is the fired produce at the
output neuron.  Work per (b, t) is ``O(m·(1 + K_in))`` — proportional to
``nnz(M_Π)``, not ``n·m``.

Grid: ``(B/bb, T/bt)`` with the whole neuron axis resident per block; the
VMEM working set is ``O(bb·bt·m)``, so the ops wrapper shrinks ``bb`` for
very wide systems.  All arithmetic is int32 (exact).  TPU is the
compilation *target*; correctness is validated in ``interpret=True`` mode
against :func:`repro.core.semantics.sparse_next_configs` (the in-kernel
gathers lower to Mosaic dynamic-gathers on real hardware — revalidate
bit-for-bit on a TPU before flipping ``interpret=False`` in production,
see ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

__all__ = ["snp_step_sparse_pallas"]


def _kernel(
    # inputs (blocks)
    c_ref,        # (bb, m)     i32 — configurations
    stride_ref,   # (bb, m)     f32 — mixed-radix strides (may be +inf)
    choices_ref,  # (bb, m)     i32 — per-neuron choice counts (>= 1)
    psi_ref,      # (bb, 1)     f32 — number of valid branches
    tab_ref,      # (bb, m, R)  i32 — packed (produce | consume << 16)
    inidx_ref,    # (m, Kin)    i32 — ELL in-adjacency, pad m
    outn_ref,     # (1,)        i32 — output neuron (m if none)
    # outputs (blocks)
    out_ref,      # (bb, bt, m) i32 — successor configs
    valid_ref,    # (bb, bt)    i32
    emis_ref,     # (bb, bt)    i32
):
    j = pl.program_id(1)   # branch-tile index
    bb, bt, m = out_ref.shape
    R = tab_ref.shape[2]
    Kin = inidx_ref.shape[1]

    # Branch ids for this tile; decode one mixed-radix digit per neuron
    # (f32 division, exact for T < 2^23 — semantics._decode_digits).
    t = j * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt, 1), 1)
    tf = t.astype(jnp.float32)
    stride = stride_ref[...].reshape(bb, 1, m)
    choices = choices_ref[...].reshape(bb, 1, m).astype(jnp.float32)
    q = jnp.floor(tf / stride)
    digits = (q - choices * jnp.floor(q / choices)).astype(jnp.int32)

    # Fired-rule actions: unrolled select over the R rule slots.
    tab = tab_ref[...]
    packed_f = jnp.zeros((bb, bt, m), jnp.int32)
    for d in range(R):  # static R, unrolled
        packed_f = jnp.where(
            digits == d, tab[:, :, d].reshape(bb, 1, m), packed_f)
    prod_f = packed_f & 0xFFFF
    cons_f = packed_f >> 16

    # ΔC via the in-adjacency: padding entries (index m) hit the appended
    # zero column, contributing nothing.
    prod_pad = jnp.concatenate(
        [prod_f, jnp.zeros((bb, bt, 1), jnp.int32)], axis=-1)
    in_idx = inidx_ref[...]
    delta = -cons_f
    for k in range(Kin):  # static K_in, unrolled
        delta = delta + jnp.take(prod_pad, in_idx[:, k], axis=-1)

    out_ref[...] = c_ref[...].reshape(bb, 1, m) + delta
    tf = t.reshape(1, bt).astype(jnp.float32)
    valid_ref[...] = (tf < psi_ref[...]).astype(jnp.int32)
    emis_ref[...] = jnp.take(prod_pad, outn_ref[0], axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "interpret"),
)
def snp_step_sparse_pallas(
    configs: jnp.ndarray,    # (B, m) int32, B % block_b == 0
    stride: jnp.ndarray,     # (B, m) float32 (saturating, may be +inf)
    choices: jnp.ndarray,    # (B, m) int32
    psi: jnp.ndarray,        # (B,) float32
    tab: jnp.ndarray,        # (B, m, R) int32 packed rule table
    in_idx: jnp.ndarray,     # (m, Kin) int32
    out_neuron: jnp.ndarray,  # () int32 — m if no output neuron
    *,
    max_branches: int,
    block_b: int = 8,
    block_t: int = 32,
    interpret: bool = True,
):
    """Raw tiled kernel call.  Use :mod:`..sparse_ops` for the padded
    public API."""
    B, m = configs.shape
    R = tab.shape[2]
    Kin = in_idx.shape[1]
    T = max_branches
    assert B % block_b == 0 and T % block_t == 0, (
        "sparse_ops.py must pad shapes to block multiples"
    )
    grid = (B // block_b, T // block_t)

    out, valid, emis = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, m, R), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((m, Kin), lambda i, j: (0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_t, m), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, m), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
        ],
        compiler_params=None if interpret else _CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(
        configs.astype(jnp.int32),
        stride.astype(jnp.float32),
        choices.astype(jnp.int32),
        psi.reshape(B, 1).astype(jnp.float32),
        tab.astype(jnp.int32),
        in_idx.astype(jnp.int32),
        out_neuron.reshape(1).astype(jnp.int32),
    )
    return out, valid.astype(bool), emis
