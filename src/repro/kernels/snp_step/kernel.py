"""Fused SNP transition kernel (Pallas, TPU).

One kernel computes, for a frontier tile of configurations and a tile of
branch indices, the successor configurations

    C'[b, t, :] = C[b, :] + S[b, t, :] · M        (paper eq. 2)

where the spiking vector ``S[b, t]`` is *decoded on the fly* from the branch
index ``t`` (mixed-radix rank decode, DESIGN.md §2) — ``S`` never
materializes in HBM.  The decode itself is phrased as an MXU matmul:

    digits[b, t, μ]   = (t // stride[b, μ]) % choices[b, μ]      (VPU, int)
    digits_r[b, t, i] = digits · onehotᵀ   (neuron-of-rule gather == matmul)
    S[b, t, i]        = app[b, i] ⊙ (digits_r[b, t, i] == rank[b, i])
    C'                = C + S · M                                (MXU)

Grid: ``(B/bb, T/bt, n/bn)`` with the rule dimension innermost and
accumulated into the revisited output block, so systems whose ``M`` exceeds
VMEM still stream through.  Block defaults keep the working set
(digit scratch + onehot/M tiles + S tile) within ~8 MB of VMEM and all
matmul dims at multiples of the 128-lane MXU.

**Shard consumption** (DESIGN.md §3 "Kernel lowering"): the same body also
serves one neuron shard of a :class:`~repro.core.plan.ShardedCompiled`.
The shard's dense lowering (``PallasBackend.lower``) restricts each local
rule's row to local columns (``M_local``), and the produce of *remote*
in-neighbors arrives as a halo input (exchanged outside the kernel) that
is folded in as one extra MXU matmul against the static 0/1 halo
in-adjacency: ``C' = C + halo·H_adj + S·M_local``.  Dummy padding rules
are never applicable (``app = 0``), so their rows contribute nothing.

TPU is the compilation *target*; correctness is validated in
``interpret=True`` mode against :mod:`repro.kernels.snp_step.ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

__all__ = ["snp_step_pallas"]


def _make_kernel(has_halo: bool):
    """Body specialized to whether a shard halo input is present (keeps
    the ref list static for ``pallas_call``)."""

    def kernel(*refs):
        it = iter(refs)
        c_ref = next(it)        # (bb, m)  f32 — configurations
        rank_ref = next(it)     # (bb, bn) f32 — rank among applicable
        app_ref = next(it)      # (bb, bn) f32 — applicability mask
        stride_ref = next(it)   # (bb, m)  i32 — radix strides (clamped)
        choices_ref = next(it)  # (bb, m)  i32 — per-neuron choice counts
        psi_ref = next(it)      # (bb, 1)  f32 — number of valid branches
        onehot_ref = next(it)   # (m, bn)  f32 — neuron→rule incidence
        mat_ref = next(it)      # (bn, m)  f32 — M_Π block
        env_ref = next(it)      # (bn, 1)  f32 — emission weights
        if has_halo:
            halo_ref = next(it)  # (bb, bt, H) f32 — remote fired produce
            hadj_ref = next(it)  # (H, m)      f32 — halo 0/1 in-adjacency
        out_ref = next(it)      # (bb, bt, m) f32 — accumulated over k
        valid_ref = next(it)    # (bb, bt) i32
        emis_ref = next(it)     # (bb, bt) f32 (accumulated over k)
        digit_ref = next(it)    # (bb, bt, m) f32 scratch, persists across k

        j = pl.program_id(1)   # branch-tile index
        k = pl.program_id(2)   # rule-tile index (innermost, accumulated)
        bb, bt, m = out_ref.shape

        @pl.when(k == 0)
        def _init():
            # Branch ids for this tile.
            t = (j * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt, 1), 1))
            stride = stride_ref[...].reshape(bb, 1, m)
            choices = choices_ref[...].reshape(bb, 1, m)
            digits = (t // stride) % choices                 # (bb, bt, m) i32
            digit_ref[...] = digits.astype(jnp.float32)
            # Output starts at C (broadcast over branches) plus, for a
            # shard, the halo contribution; S·M accumulates in over k.
            base = jnp.broadcast_to(
                c_ref[...].reshape(bb, 1, m), (bb, bt, m))
            if has_halo:
                base = base + jax.lax.dot_general(
                    halo_ref[...], hadj_ref[...],
                    (((2,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
            out_ref[...] = base
            emis_ref[...] = jnp.zeros((bb, bt), jnp.float32)
            tf = t.reshape(1, bt).astype(jnp.float32)
            valid_ref[...] = (tf < psi_ref[...]).astype(jnp.int32)

        digits = digit_ref[...]                               # (bb, bt, m)
        # "gather digit of each rule's neuron" as an MXU matmul with the
        # 0/1 incidence: digits_r[b,t,i] = Σ_μ digits[b,t,μ]·onehot[μ,i].
        digits_r = jax.lax.dot_general(
            digits, onehot_ref[...],
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (bb, bt, bn)
        s = app_ref[...].reshape(bb, 1, -1) * (
            digits_r == rank_ref[...].reshape(bb, 1, -1)
        ).astype(jnp.float32)                                 # (bb, bt, bn)
        out_ref[...] += jax.lax.dot_general(
            s, mat_ref[...],
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        emis_ref[...] += jax.lax.dot_general(
            s, env_ref[...],
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bb, bt)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "block_n",
                     "interpret"),
)
def snp_step_pallas(
    configs: jnp.ndarray,    # (B, m) int32, B % block_b == 0
    rank: jnp.ndarray,       # (B, n) int32
    app: jnp.ndarray,        # (B, n) bool
    stride: jnp.ndarray,     # (B, m) int32 (pre-clamped < 2^30)
    choices: jnp.ndarray,    # (B, m) int32
    psi: jnp.ndarray,        # (B,) float32
    onehot: jnp.ndarray,     # (n, m) int8 — rule→neuron incidence
    M: jnp.ndarray,          # (n, m) int32
    env: jnp.ndarray,        # (n,) int32
    halo: jnp.ndarray = None,   # (B, T, H) int32 — shard halo produce
    hadj: jnp.ndarray = None,   # (H, m) int8 — halo 0/1 in-adjacency
    *,
    max_branches: int,
    block_b: int,
    block_t: int,
    block_n: int,
    interpret: bool = True,
):
    """Raw tiled kernel call.  Use :mod:`..ops` for the padded public API
    — the block shape is *required* here: the grid/tile choice belongs to
    the caller (ultimately a :class:`~repro.core.plan.KernelConfig` on
    the plan, DESIGN.md §3 "Planner & autotuner"), not the kernel.
    ``halo``/``hadj`` select the shard body (module docstring)."""
    B, m = configs.shape
    n = rank.shape[1]
    T = max_branches
    assert B % block_b == 0 and T % block_t == 0 and n % block_n == 0, (
        "ops.py must pad shapes to block multiples"
    )
    has_halo = halo is not None
    grid = (B // block_b, T // block_t, n // block_n)

    in_specs = [
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
        pl.BlockSpec((m, block_n), lambda i, j, k: (0, k)),
        pl.BlockSpec((block_n, m), lambda i, j, k: (k, 0)),
        pl.BlockSpec((block_n, 1), lambda i, j, k: (k, 0)),
    ]
    operands = [
        configs.astype(jnp.float32),
        rank.astype(jnp.float32),
        app.astype(jnp.float32),
        stride.astype(jnp.int32),
        choices.astype(jnp.int32),
        psi.reshape(B, 1).astype(jnp.float32),
        onehot.T.astype(jnp.float32),   # (m, n)
        M.astype(jnp.float32),
        env.reshape(n, 1).astype(jnp.float32),
    ]
    if has_halo:
        H = halo.shape[-1]
        in_specs += [
            pl.BlockSpec((block_b, block_t, H), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((H, m), lambda i, j, k: (0, 0)),
        ]
        operands += [halo.astype(jnp.float32), hadj.astype(jnp.float32)]

    out, valid, emis = pl.pallas_call(
        _make_kernel(has_halo),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, block_t, m), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((block_b, block_t), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_t), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, m), jnp.float32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, block_t, m), jnp.float32),
        ],
        compiler_params=None if interpret else _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out.astype(jnp.int32), valid.astype(bool), emis.astype(jnp.int32)
