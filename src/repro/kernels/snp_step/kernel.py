"""Fused SNP transition kernel (Pallas, TPU).

One kernel computes, for a frontier tile of configurations and a tile of
branch indices, the successor configurations

    C'[b, t, :] = C[b, :] + S[b, t, :] · M        (paper eq. 2)

where the spiking vector ``S[b, t]`` is *decoded on the fly* from the branch
index ``t`` (mixed-radix rank decode, DESIGN.md §2) — ``S`` never
materializes in HBM.  The decode itself is phrased as an MXU matmul:

    digits[b, t, μ]   = (t // stride[b, μ]) % choices[b, μ]      (VPU, int)
    digits_r[b, t, i] = digits · onehotᵀ   (neuron-of-rule gather == matmul)
    S[b, t, i]        = app[b, i] ⊙ (digits_r[b, t, i] == rank[b, i])
    C'                = C + S · M                                (MXU)

Grid: ``(B/bb, T/bt, n/bn)`` with the rule dimension innermost and
accumulated into the revisited output block, so systems whose ``M`` exceeds
VMEM still stream through.  Block defaults keep the working set
(digit scratch + onehot/M tiles + S tile) within ~8 MB of VMEM and all
matmul dims at multiples of the 128-lane MXU.

**Shard consumption** (DESIGN.md §3 "Kernel lowering"): the same body also
serves one neuron shard of a :class:`~repro.core.plan.ShardedCompiled`.
The shard's dense lowering (``PallasBackend.lower``) restricts each local
rule's row to local columns (``M_local``), and the produce of *remote*
in-neighbors arrives as a halo input (exchanged outside the kernel) that
is folded in as one extra MXU matmul against the static 0/1 halo
in-adjacency: ``C' = C + halo·H_adj + S·M_local``.  Dummy padding rules
are never applicable (``app = 0``), so their rows contribute nothing.

**Delayed semantics** (DESIGN.md "Delayed semantics"): the same grid also
runs the ``semantics="delays"`` tier.  ``M`` is swapped for the stacked
``(n, 4m)`` weight matrix ``W`` so the accumulated contraction ``S·W``
yields each fired rule's ``[consume | produce·(d=0) | delay |
produce·(d>0)]`` into a VMEM accumulator; after the last rule tile one
combine stage applies the closed-neuron algebra (reopen-pending fanout
over the 0/1 adjacency, reception gate, countdown/pending update) and
writes ``(bb, bt, 3m)`` state rows ``[spikes | countdown | pending]``.

TPU is the compilation *target*; correctness is validated in
``interpret=True`` mode against :mod:`repro.kernels.snp_step.ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

__all__ = ["snp_step_pallas"]


def _make_kernel(has_halo: bool, has_delay: bool = False):
    """Body specialized to whether a shard halo input is present and
    whether the step runs the delayed-semantics tier (keeps the ref list
    static for ``pallas_call``).  The two are mutually exclusive: no
    backend shards ``semantics="delays"`` (plan.py refuses)."""
    assert not (has_halo and has_delay)

    def kernel(*refs):
        it = iter(refs)
        c_ref = next(it)        # (bb, m)  f32 — configurations (spikes)
        rank_ref = next(it)     # (bb, bn) f32 — rank among applicable
        app_ref = next(it)      # (bb, bn) f32 — applicability mask
        stride_ref = next(it)   # (bb, m)  i32 — radix strides (clamped)
        choices_ref = next(it)  # (bb, m)  i32 — per-neuron choice counts
        psi_ref = next(it)      # (bb, 1)  f32 — number of valid branches
        onehot_ref = next(it)   # (m, bn)  f32 — neuron→rule incidence
        mat_ref = next(it)      # (bn, m)  f32 — M_Π block; (bn, 4m) W
        #                         block under delays (delayed_weight_matrix)
        if not has_delay:
            env_ref = next(it)  # (bn, 1)  f32 — emission weights
        if has_halo:
            halo_ref = next(it)  # (bb, bt, H) f32 — remote fired produce
            hadj_ref = next(it)  # (H, m)      f32 — halo 0/1 in-adjacency
        if has_delay:
            cd_ref = next(it)    # (bb, m) f32 — countdowns
            pd_ref = next(it)    # (bb, m) f32 — pending spikes
            adj_ref = next(it)   # (m, m)  f32 — 0/1 synapse adjacency
            outoh_ref = next(it)  # (m, 1) f32 — output-neuron one-hot
        out_ref = next(it)      # (bb, bt, m|3m) f32 — accumulated over k
        valid_ref = next(it)    # (bb, bt) i32
        emis_ref = next(it)     # (bb, bt) f32 (accumulated over k)
        digit_ref = next(it)    # (bb, bt, m) f32 scratch, persists across k
        if has_delay:
            acc_ref = next(it)  # (bb, bt, 4m) f32 scratch — S·W accumulator

        j = pl.program_id(1)   # branch-tile index
        k = pl.program_id(2)   # rule-tile index (innermost, accumulated)
        bb, bt, _ = out_ref.shape
        m = c_ref.shape[-1]

        @pl.when(k == 0)
        def _init():
            # Branch ids for this tile.
            t = (j * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt, 1), 1))
            stride = stride_ref[...].reshape(bb, 1, m)
            choices = choices_ref[...].reshape(bb, 1, m)
            digits = (t // stride) % choices                 # (bb, bt, m) i32
            digit_ref[...] = digits.astype(jnp.float32)
            if has_delay:
                acc_ref[...] = jnp.zeros((bb, bt, 4 * m), jnp.float32)
            else:
                # Output starts at C (broadcast over branches) plus, for a
                # shard, the halo contribution; S·M accumulates in over k.
                base = jnp.broadcast_to(
                    c_ref[...].reshape(bb, 1, m), (bb, bt, m))
                if has_halo:
                    base = base + jax.lax.dot_general(
                        halo_ref[...], hadj_ref[...],
                        (((2,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    )
                out_ref[...] = base
            emis_ref[...] = jnp.zeros((bb, bt), jnp.float32)
            tf = t.reshape(1, bt).astype(jnp.float32)
            valid_ref[...] = (tf < psi_ref[...]).astype(jnp.int32)

        digits = digit_ref[...]                               # (bb, bt, m)
        # "gather digit of each rule's neuron" as an MXU matmul with the
        # 0/1 incidence: digits_r[b,t,i] = Σ_μ digits[b,t,μ]·onehot[μ,i].
        digits_r = jax.lax.dot_general(
            digits, onehot_ref[...],
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (bb, bt, bn)
        s = app_ref[...].reshape(bb, 1, -1) * (
            digits_r == rank_ref[...].reshape(bb, 1, -1)
        ).astype(jnp.float32)                                 # (bb, bt, bn)
        if not has_delay:
            out_ref[...] += jax.lax.dot_general(
                s, mat_ref[...],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            emis_ref[...] += jax.lax.dot_general(
                s, env_ref[...],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(bb, bt)
            return

        # Delayed tier: accumulate the stacked contraction S·W — per
        # (branch, neuron) the fired rule's [consume | produce·(d=0) | d |
        # produce·(d>0)] — then combine once after the last rule tile
        # (matches core.semantics.delayed_next_configs bit-for-bit).
        acc_ref[...] += jax.lax.dot_general(
            s, mat_ref[...],
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(k == pl.num_programs(2) - 1)
        def _combine():
            acc = acc_ref[...]
            cons_f = acc[..., :m]
            emit_fired = acc[..., m:2 * m]
            d_f = acc[..., 2 * m:3 * m]
            prod_pend = acc[..., 3 * m:]
            cd = cd_ref[...].reshape(bb, 1, m)
            pd = pd_ref[...].reshape(bb, 1, m)

            reopen = cd == 1.0
            emit = emit_fired + jnp.where(reopen, pd, 0.0)
            incoming = jax.lax.dot_general(
                emit, adj_ref[...],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            fired_del = d_f > 0.0
            cd_next = jnp.where(fired_del, d_f, jnp.maximum(cd - 1.0, 0.0))
            gate = cd_next == 0.0
            spikes = c_ref[...].reshape(bb, 1, m) - cons_f \
                + jnp.where(gate, incoming, 0.0)
            pd_next = jnp.where(fired_del, prod_pend,
                                jnp.where(reopen, 0.0, pd))
            out_ref[...] = jnp.concatenate(
                [spikes, cd_next, pd_next], axis=-1)
            emis_ref[...] = jax.lax.dot_general(
                emit, outoh_ref[...],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(bb, bt)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "block_n",
                     "interpret"),
)
def snp_step_pallas(
    configs: jnp.ndarray,    # (B, m) int32, B % block_b == 0
    rank: jnp.ndarray,       # (B, n) int32
    app: jnp.ndarray,        # (B, n) bool
    stride: jnp.ndarray,     # (B, m) int32 (pre-clamped < 2^30)
    choices: jnp.ndarray,    # (B, m) int32
    psi: jnp.ndarray,        # (B,) float32
    onehot: jnp.ndarray,     # (n, m) int8 — rule→neuron incidence
    M: jnp.ndarray,          # (n, m) int32
    env: jnp.ndarray,        # (n,) int32 — ignored under delays
    halo: jnp.ndarray = None,   # (B, T, H) int32 — shard halo produce
    hadj: jnp.ndarray = None,   # (H, m) int8 — halo 0/1 in-adjacency
    cd: jnp.ndarray = None,     # (B, m) int32 — countdowns (delays tier)
    pd: jnp.ndarray = None,     # (B, m) int32 — pending spikes
    adj: jnp.ndarray = None,    # (m, m) int32 — 0/1 synapse adjacency
    outoh: jnp.ndarray = None,  # (m,) int32 — output-neuron one-hot
    *,
    max_branches: int,
    block_b: int,
    block_t: int,
    block_n: int,
    interpret: bool = True,
):
    """Raw tiled kernel call.  Use :mod:`..ops` for the padded public API
    — the block shape is *required* here: the grid/tile choice belongs to
    the caller (ultimately a :class:`~repro.core.plan.KernelConfig` on
    the plan, DESIGN.md §3 "Planner & autotuner"), not the kernel.
    ``halo``/``hadj`` select the shard body (module docstring);
    ``cd``/``pd``/``adj``/``outoh`` select the delayed-semantics body,
    with ``M`` carrying the stacked (n, 4m) weight matrix
    (:func:`repro.core.semantics.delayed_weight_matrix`) and the output
    widening to ``(B, T, 3m)`` state rows."""
    B, m = configs.shape
    n = rank.shape[1]
    T = max_branches
    assert B % block_b == 0 and T % block_t == 0 and n % block_n == 0, (
        "ops.py must pad shapes to block multiples"
    )
    has_halo = halo is not None
    has_delay = cd is not None
    assert not (has_halo and has_delay), \
        "sharded delayed lowering is unsupported (plan.py refuses it)"
    out_m = 3 * m if has_delay else m
    grid = (B // block_b, T // block_t, n // block_n)

    in_specs = [
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
        pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
        pl.BlockSpec((m, block_n), lambda i, j, k: (0, k)),
        pl.BlockSpec((block_n, M.shape[-1]), lambda i, j, k: (k, 0)),
    ]
    operands = [
        configs.astype(jnp.float32),
        rank.astype(jnp.float32),
        app.astype(jnp.float32),
        stride.astype(jnp.int32),
        choices.astype(jnp.int32),
        psi.reshape(B, 1).astype(jnp.float32),
        onehot.T.astype(jnp.float32),   # (m, n)
        M.astype(jnp.float32),          # (n, m); (n, 4m) W under delays
    ]
    if not has_delay:
        in_specs += [pl.BlockSpec((block_n, 1), lambda i, j, k: (k, 0))]
        operands += [env.reshape(n, 1).astype(jnp.float32)]
    if has_halo:
        H = halo.shape[-1]
        in_specs += [
            pl.BlockSpec((block_b, block_t, H), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((H, m), lambda i, j, k: (0, 0)),
        ]
        operands += [halo.astype(jnp.float32), hadj.astype(jnp.float32)]
    if has_delay:
        in_specs += [
            pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i, j, k: (i, 0)),
            pl.BlockSpec((m, m), lambda i, j, k: (0, 0)),
            pl.BlockSpec((m, 1), lambda i, j, k: (0, 0)),
        ]
        operands += [
            cd.astype(jnp.float32),
            pd.astype(jnp.float32),
            adj.astype(jnp.float32),
            outoh.reshape(m, 1).astype(jnp.float32),
        ]

    scratch_shapes = [pltpu.VMEM((block_b, block_t, m), jnp.float32)]
    if has_delay:
        scratch_shapes += [pltpu.VMEM((block_b, block_t, 4 * m),
                                      jnp.float32)]

    out, valid, emis = pl.pallas_call(
        _make_kernel(has_halo, has_delay),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, block_t, out_m),
                         lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((block_b, block_t), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_t), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, out_m), jnp.float32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.float32),
        ],
        scratch_shapes=scratch_shapes,
        compiler_params=None if interpret else _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out.astype(jnp.int32), valid.astype(bool), emis.astype(jnp.int32)
