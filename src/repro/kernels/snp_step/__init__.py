"""Fused SNP transition kernel (Pallas TPU) — decode + S·M + C in VMEM.

Reaches production consumers through
:class:`repro.core.backend.PallasBackend` (``backend="pallas"``); keep the
raw entry points here for kernel tests and benchmarks."""

from .kernel import snp_step_pallas
from .ops import snp_step
from .ref import snp_step_ref

__all__ = ["snp_step", "snp_step_pallas", "snp_step_ref"]
