"""Fused SNP transition kernels (Pallas TPU).

Two variants behind the step-backend registry:

* dense — decode + S·M + C in VMEM, streaming the ``(n, m)`` matrix
  through the MXU (:class:`repro.core.backend.PallasBackend`,
  ``backend="pallas"``);
* sparse — decode + selection lookup + ELL in-adjacency gather, work
  proportional to ``nnz(M_Π)``
  (:class:`repro.core.backend.SparsePallasBackend`,
  ``backend="sparse_pallas"``).

Both bodies are parameterized by the plan's encoding metadata
(DESIGN.md §3 "Kernel lowering"): the sparse kernel carries an in-kernel
COO segment-sum stage for hybrid ELL+COO plans, and the ``*_shard``
wrappers consume one neuron shard of a
:class:`~repro.core.plan.ShardedCompiled` (extended-index / halo form)
inside ``explore_distributed``.

Keep the raw entry points here for kernel tests and benchmarks."""

from .kernel import snp_step_pallas
from .ops import snp_step, snp_step_dense_shard
from .ref import snp_step_ref
from .sparse_kernel import snp_step_sparse_pallas
from .sparse_ops import snp_step_sparse, snp_step_sparse_shard

__all__ = ["snp_step", "snp_step_dense_shard", "snp_step_pallas",
           "snp_step_ref", "snp_step_sparse", "snp_step_sparse_pallas",
           "snp_step_sparse_shard"]
