"""Fused SNP transition kernel (Pallas TPU) — decode + S·M + C in VMEM."""

from .kernel import snp_step_pallas
from .ops import snp_step
from .ref import snp_step_ref

__all__ = ["snp_step", "snp_step_pallas", "snp_step_ref"]
