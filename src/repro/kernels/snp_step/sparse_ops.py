"""Public jit'd wrappers around the fused sparse SNP transition kernel.

Mirrors :mod:`.ops` for the dense kernel: computes the cheap ``O(B·m·R)``
per-config bookkeeping with the reference sparse semantics (applicability,
ranks, radix strides, and the packed fired-rule table the kernel gathers
from), pads the batch/branch dimensions to block multiples (padding rows
decode digit 0 into all-zero tables: no valid branches, no contribution),
and unpads/masks the results.

Two entry points over the one encoding-parameterized kernel body
(DESIGN.md §3 "Kernel lowering"):

* :func:`snp_step_sparse` — single-device step on a
  :class:`~repro.core.matrix.CompiledSparseSNP`; pure-ELL **and** hybrid
  ELL+COO encodings (the COO segment-sum stage runs in-kernel from the
  compiler's ``coo_bounds``/``hub_slot`` metadata).
* :func:`snp_step_sparse_shard` — one neuron shard of a
  :class:`~repro.core.plan.ShardedCompiled`: the caller
  (``explore_distributed``'s sharded step) passes the already-combined
  cross-shard strides/Ψ and the received halo produce; ``in_idx`` indexes
  the extended ``[local | halo | zero]`` space.  Traceable inside
  ``shard_map`` — the halo ``all_to_all`` stays outside the kernel.

On CPU the kernels run in interpret mode; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.matrix import CompiledSparseSNP, is_delayed
from repro.core.plan import KernelConfig
from repro.core.semantics import (delayed_packed_actions, packed_rule_table,
                                  sparse_branch_info,
                                  sparse_delayed_branch_info, split_state)

from .sparse_kernel import snp_step_sparse_pallas

__all__ = ["snp_step_sparse", "snp_step_sparse_shard"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _resolve_blocks(kernel: Optional[KernelConfig], block_b, block_t):
    """The effective sparse block shape: explicit per-axis kwarg >
    ``kernel`` config field > :meth:`KernelConfig.sparse_default`.  A
    config asking for neuron-axis tiling (``block_n``) is a clear error —
    this kernel keeps the whole neuron axis resident per block."""
    if kernel is not None and kernel.block_n is not None:
        raise ValueError(
            f"sparse kernel config sets block_n={kernel.block_n}, but the "
            "sparse lowering keeps the whole neuron axis resident per "
            "block (grid (B/bb, T/bt)); drop block_n — only the dense "
            "kernel tiles that axis")
    base = KernelConfig.sparse_default() if kernel is None else \
        KernelConfig.sparse_default().merged(
            block_b=kernel.block_b, block_t=kernel.block_t)
    cfg = base.merged(block_b=block_b, block_t=block_t)
    return cfg.block_b, cfg.block_t


def _pad_bt(x, rows, branches=None, value=0):
    """Zero/value-pad the batch axis (axis 0) and optionally the branch
    axis (axis 1) to block multiples — shared by both wrappers so padding
    semantics can't diverge.  (Distinct name and axes from the dense
    wrapper's ``ops._pad``, which pads leading/trailing axes.)"""
    pads = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    if branches is not None:
        pads[1] = (0, branches - x.shape[1])
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "kernel",
                     "interpret"),
)
def snp_step_sparse(
    configs: jnp.ndarray,   # (B, m) int32
    comp: CompiledSparseSNP,
    *,
    max_branches: int,
    block_b: Optional[int] = None,
    block_t: Optional[int] = None,
    kernel: Optional[KernelConfig] = None,
    interpret: bool = True,
):
    """Fused sparse successor expansion: returns (successors (B,T,m) int32,
    valid (B,T) bool, emissions (B,T) int32, overflow (B,) bool).

    The block shape comes from ``kernel`` (a hashable
    :class:`~repro.core.plan.KernelConfig`, usually carried by a
    ``SystemPlan``), overridable per axis with the explicit kwargs;
    unset axes fall back to :meth:`KernelConfig.sparse_default`.

    Bit-identical to :func:`repro.core.semantics.sparse_next_configs` (and
    hence to the dense oracle on valid entries for spike counts < 2^24),
    for pure-ELL and hybrid ELL+COO encodings alike.  A delayed ``comp``
    (``semantics="delays"``; 3m-wide state rows) routes through the
    kernel's delay stage and returns ``(B, T, 3m)`` successors,
    bit-identical to
    :func:`repro.core.semantics.sparse_delayed_next_configs`.
    """
    B = configs.shape[0]
    m = comp.num_neurons
    T = max_branches
    delayed = is_delayed(comp)
    block_b, block_t = _resolve_blocks(kernel, block_b, block_t)

    if comp.coo_src.shape[0] and (comp.coo_bounds is None
                                  or comp.hub_slot is None):
        # Static-shape check, so this raises at trace time with a real
        # message instead of a shape crash deep in the kernel.  Only
        # hand-built encodings can get here: compile_system_sparse always
        # emits the segment metadata the in-kernel COO stage consumes.
        raise ValueError(
            "snp_step_sparse: hybrid ELL+COO encoding without COO lowering "
            "metadata (coo_bounds/hub_slot); lower the system through "
            "compile_system_sparse / backend.compile instead of building "
            "the CompiledSparseSNP by hand")

    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)

    if delayed:
        spikes, cd, pd = split_state(configs)
        info = sparse_delayed_branch_info(configs, comp)
        packed_e, packed_d = delayed_packed_actions(comp)
        tab = packed_rule_table(info, comp, packed_e)         # (B, m, R)
        dtab = packed_rule_table(info, comp, packed_d)
    else:
        spikes, cd, pd, dtab = configs, None, None, None
        info = sparse_branch_info(configs, comp)
        tab = packed_rule_table(info, comp)                   # (B, m, R)

    Bp, Tp = _round_up(B, block_b), _round_up(T, block_t)

    out, valid, emis = snp_step_sparse_pallas(
        _pad_bt(spikes, Bp),
        # padded configs: stride 1 / choices 1 / psi 0 -> no valid branches
        _pad_bt(info.stride, Bp, value=1),
        _pad_bt(info.choices.astype(jnp.int32), Bp, value=1),
        _pad_bt(info.psi, Bp),
        _pad_bt(tab, Bp),
        comp.in_idx,
        comp.out_neuron,
        coo_src=comp.coo_src if comp.coo_src.shape[0] else None,
        coo_bounds=comp.coo_bounds if comp.coo_src.shape[0] else None,
        hub_slot=comp.hub_slot if comp.coo_src.shape[0] else None,
        dtab=_pad_bt(dtab, Bp) if delayed else None,
        cd=_pad_bt(cd, Bp) if delayed else None,
        pd=_pad_bt(pd, Bp) if delayed else None,
        max_branches=Tp,
        block_b=block_b, block_t=block_t,
        interpret=interpret,
    )
    out = out[:B, :T]
    valid = valid[:B, :T] & info.alive[:, None]
    emis = emis[:B, :T]
    overflow = info.psi > float(T)
    return out, valid, emis, overflow


def snp_step_sparse_shard(
    configs: jnp.ndarray,   # (B, mloc) int32 — local frontier slices
    stride: jnp.ndarray,    # (B, mloc) f32 — cross-shard-combined strides
    choices: jnp.ndarray,   # (B, mloc) int32 — local choice counts
    psi: jnp.ndarray,       # (B,) f32 — replicated global Ψ
    tab: jnp.ndarray,       # (B, mloc, R) int32 — local packed rule table
    in_idx: jnp.ndarray,    # (mloc, Kin) int32 — extended-space indices
    halo: jnp.ndarray,      # (B, T, H) int32 — received remote produce
    *,
    max_branches: int,
    block_b: Optional[int] = None,
    block_t: Optional[int] = None,
    kernel: Optional[KernelConfig] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """One shard's candidate slices ``(B, T, mloc)`` through the fused
    kernel.  Bookkeeping (branch info, radix combine, the halo exchange)
    belongs to the caller — this wrapper only pads to block multiples and
    routes the extended encoding into the kernel body.  Traceable (called
    inside ``explore_distributed``'s ``shard_map``)."""
    B, mloc = configs.shape
    T = max_branches
    H = halo.shape[-1]
    block_b, block_t = _resolve_blocks(kernel, block_b, block_t)
    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)
    Bp, Tp = _round_up(B, block_b), _round_up(T, block_t)

    # The emission gather index is the extended zero slot: shard emissions
    # are judged by the driver, not here.
    out, _, _ = snp_step_sparse_pallas(
        _pad_bt(configs, Bp),
        _pad_bt(stride, Bp, value=1),
        _pad_bt(choices, Bp, value=1),
        _pad_bt(psi, Bp),
        _pad_bt(tab, Bp),
        in_idx,
        jnp.asarray(mloc + H, jnp.int32),
        halo=_pad_bt(halo, Bp, branches=Tp),
        max_branches=Tp,
        block_b=block_b, block_t=block_t,
        interpret=interpret,
    )
    return out[:B, :T]
