"""Public jit'd wrapper around the fused sparse SNP transition kernel.

Mirrors :mod:`.ops` for the dense kernel: computes the cheap ``O(B·m·R)``
per-config bookkeeping with the reference sparse semantics (applicability,
ranks, radix strides, and the packed fired-rule table the kernel gathers
from), pads the batch/branch dimensions to block multiples (padding rows
decode digit 0 into all-zero tables: no valid branches, no contribution),
and unpads/masks the results.

On CPU the kernel runs in interpret mode; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.matrix import CompiledSparseSNP
from repro.core.semantics import packed_rule_table, sparse_branch_info

from .sparse_kernel import snp_step_sparse_pallas

__all__ = ["snp_step_sparse"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@functools.partial(
    jax.jit,
    static_argnames=("max_branches", "block_b", "block_t", "interpret"),
)
def snp_step_sparse(
    configs: jnp.ndarray,   # (B, m) int32
    comp: CompiledSparseSNP,
    *,
    max_branches: int,
    block_b: int = 8,
    block_t: int = 32,
    interpret: bool = True,
):
    """Fused sparse successor expansion: returns (successors (B,T,m) int32,
    valid (B,T) bool, emissions (B,T) int32, overflow (B,) bool).

    Bit-identical to :func:`repro.core.semantics.sparse_next_configs` (and
    hence to the dense oracle on valid entries for spike counts < 2^24).
    """
    B, m = configs.shape
    T = max_branches

    if comp.coo_src.shape[0]:
        # Static-shape check, so this raises at trace time with a real
        # message instead of a shape crash deep in the kernel.
        raise NotImplementedError(
            "snp_step_sparse: the fused kernel supports only the pure-ELL "
            "in-adjacency; this system was compiled with a hybrid ELL+COO "
            f"plan ({int(comp.coo_src.shape[0])} tail synapses).  Use "
            "backend='sparse' (the SparsePallasBackend falls back to it "
            "automatically with a warning).")

    block_b = min(block_b, max(B, 1))
    block_t = min(block_t, T)

    info = sparse_branch_info(configs, comp)
    tab = packed_rule_table(info, comp)                      # (B, m, R)

    Bp, Tp = _round_up(B, block_b), _round_up(T, block_t)

    def pad_rows(x, value=0):
        pads = [(0, Bp - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pads, constant_values=value)

    out, valid, emis = snp_step_sparse_pallas(
        pad_rows(configs),
        # padded configs: stride 1 / choices 1 / psi 0 -> no valid branches
        pad_rows(info.stride, value=1),
        pad_rows(info.choices.astype(jnp.int32), value=1),
        pad_rows(info.psi),
        pad_rows(tab),
        comp.in_idx,
        comp.out_neuron,
        max_branches=Tp,
        block_b=block_b, block_t=block_t,
        interpret=interpret,
    )
    out = out[:B, :T]
    valid = valid[:B, :T] & info.alive[:, None]
    emis = emis[:B, :T]
    overflow = info.psi > float(T)
    return out, valid, emis, overflow
