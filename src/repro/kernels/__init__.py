"""Pallas TPU kernels for the framework's compute hot-spots.

* :mod:`repro.kernels.snp_step` — the paper's transition (decode + S·M + C).
  Served to every workload (explore / run_traces / distributed / the SNP
  trace service) as the ``"pallas"`` entry of the step-backend registry
  (:mod:`repro.core.backend`).
* :mod:`repro.kernels.flash_attn` — flash attention for LM prefill.

Each kernel ships a ``kernel.py`` (pl.pallas_call + BlockSpec), an
``ops.py`` jit'd public wrapper, and a ``ref.py`` pure-jnp oracle; tests
sweep shapes/dtypes and assert allclose (exact, for integer workloads)
against the oracle in interpret mode.
"""
