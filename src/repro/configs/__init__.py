"""Architecture registry: one module per assigned arch + the paper's SNP
workloads.  ``get_config(name)`` / ``list_archs()`` are the public API."""

from .base import ArchConfig, SHAPES, ShapeSpec, get_config, list_archs, shape_for

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        command_r_35b,
        grok1_314b,
        jamba15_large,
        minicpm3_4b,
        minicpm_2b,
        musicgen_medium,
        qwen2_moe_a2_7b,
        qwen2_vl_7b,
        rwkv6_7b,
        smollm_360m,
    )


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs",
           "shape_for"]
