"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L, d_model 8192, 64 heads (GQA kv=8), d_ff 22528, vocab 256000.
No biases; Cohere-style parallel attention+MLP block; tied embeddings.
"""

from .base import ArchConfig, register


@register("command-r-35b")
def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        rope_theta=8e6,
        parallel_block=True,
        tie_embeddings=True,
    )
