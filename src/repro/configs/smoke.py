"""Reduced same-family configs for CPU smoke tests.

Every assigned architecture gets a tiny sibling that preserves its
*structural* features (GQA ratio, MLA ranks, MoE top-k, hybrid pattern,
M-RoPE sections, codebooks) while shrinking widths/depths so a forward +
train step runs on CPU in seconds.  Full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses

from .base import ArchConfig

__all__ = ["reduced"]


def reduced(cfg: ArchConfig) -> ArchConfig:
    r = dict(
        num_layers=2 * len(cfg.layer_pattern),
        d_model=64,
        vocab_size=128,
        d_ff=96,
        dtype="float32",
    )
    if cfg.num_heads:
        # keep the GQA ratio
        group = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kv = 2 if cfg.num_kv_heads > 1 else 1
        r["num_heads"] = kv * group
        r["num_kv_heads"] = kv
        r["head_dim"] = 16
    if cfg.mrope_sections:
        r["mrope_sections"] = (2, 3, 3)     # sums to head_dim/2 = 8
    if cfg.attention == "mla":
        r.update(q_lora_rank=24, kv_lora_rank=16,
                 qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
                 num_heads=4, num_kv_heads=4, head_dim=16)
    if cfg.num_experts:
        r.update(num_experts=4,
                 num_experts_per_tok=min(2, cfg.num_experts_per_tok),
                 moe_d_ff=32)
        if cfg.shared_expert_d_ff:
            r["shared_expert_d_ff"] = 64
    if cfg.family in ("hybrid",):
        r.update(mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
                 mamba_dt_rank=8)
    if cfg.attention == "none":
        r.update(num_heads=0, num_kv_heads=0, rwkv_head_size=16)
    name = cfg.name + "-smoke"
    return dataclasses.replace(cfg, **r, name=name)
