"""MusicGen-medium [arXiv:2306.05284].

48L, d_model 1536, 24 heads (MHA kv=24), d_ff 6144 — decoder-only over
EnCodec tokens: 4 parallel codebooks of vocab 2048 (delay-pattern streams
summed at the embedding, one LM head per codebook).  The EnCodec audio
frontend is a stub per the assignment: ``input_specs`` provides the token
streams directly.  GELU MLP (no gating).
"""

from .base import ArchConfig, register


@register("musicgen-medium")
def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        mlp_act="gelu",
        rope_theta=1e4,
        codebooks=4,
        frontend="audio_stub",
    )
