"""Grok-1 314B [hf:xai-org/grok-1; unverified].

64L, d_model 6144, 48 heads (GQA kv=8), MoE 8 experts top-2 with expert
d_ff 32768, vocab 131072.
"""

from .base import ArchConfig, register


@register("grok-1-314b")
def config() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        rope_theta=1e4,
        layer_pattern=("attn:moe",),
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=32768,
    )
