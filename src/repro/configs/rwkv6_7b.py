"""RWKV-6 (Finch) 7B [arXiv:2404.05892].

32L, d_model 4096, attention-free (WKV6 data-dependent-decay linear
recurrence, head size 64 -> 64 heads), channel-mix d_ff 14336, vocab 65536.
Supports long_500k: recurrent state is O(1) in sequence length.
"""

from .base import ArchConfig, register


@register("rwkv6-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=14336,
        vocab_size=65536,
        attention="none",
        layer_pattern=("rwkv6:none",),
        rwkv_head_size=64,
        supports_long_context=True,
    )
