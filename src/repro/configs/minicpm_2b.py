"""MiniCPM-2B [arXiv:2404.06395].

40L, d_model 2304, 36 heads (MHA kv=36), d_ff 5760, vocab 122753.
Llama-like architecture; trains with the WSD (warmup-stable-decay)
schedule — wired to the optimizer via ``schedule='wsd'``.
"""

from .base import ArchConfig, register


@register("minicpm-2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b",
        family="dense",
        num_layers=40,
        d_model=2304,
        num_heads=36,
        num_kv_heads=36,
        head_dim=64,
        d_ff=5760,
        vocab_size=122753,
        rope_theta=1e4,
        tie_embeddings=True,
        schedule="wsd",
    )
