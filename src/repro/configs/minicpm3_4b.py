"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448 — multi-head latent
attention (MLA): q LoRA rank 768, kv LoRA rank 256, qk nope/rope head dims
64/32, v head dim 64.  The KV cache stores the 256-d latent + shared 32-d
rope key: ~10x smaller than the GQA-equivalent cache.
"""

from .base import ArchConfig, register


@register("minicpm3-4b")
def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab_size=73448,
        attention="mla",
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        rope_theta=1e4,
        tie_embeddings=True,
    )
