"""Architecture configuration system.

One frozen dataclass describes every supported backbone; per-arch modules in
this package instantiate it with published numbers (``--arch <id>`` in the
launchers).  Heterogeneous stacks (hybrid attention/SSM, periodic MoE) are
expressed as a *layer pattern*: the stack is ``num_periods`` repetitions of
``layer_pattern``, and the transformer scans over periods with one compiled
period body (small HLO even for 72-layer models — essential for the
512-device dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Tuple

__all__ = ["ArchConfig", "register", "get_config", "list_archs", "SHAPES",
           "ShapeSpec", "shape_for"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # layer pattern: kinds per position within one period.  Kinds:
    #   "attn" | "mamba" | "rwkv6"  x  mlp kind "dense" | "moe" | "shared_moe"
    # encoded as f"{mixer}:{mlp}".
    layer_pattern: Tuple[str, ...] = ("attn:dense",)

    # attention
    attention: str = "gqa"          # gqa | mla | none
    attn_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim sections
    parallel_block: bool = False    # command-r style parallel attn+mlp
    mlp_act: str = "silu"           # silu | gelu

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0     # always-on shared expert (qwen2-moe)
    capacity_factor: float = 1.25
    # pad the expert dim to a multiple of this (0 = off) so it shards over
    # the model axis (expert parallelism); padded experts are never routed
    # to.  §Perf optimization, off in the paper-faithful baseline.
    expert_pad_multiple: int = 0

    # mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0          # 0 -> ceil(d_model / 16)

    # rwkv6
    rwkv_head_size: int = 64

    # io / misc
    tie_embeddings: bool = False
    codebooks: int = 0              # musicgen: parallel EnCodec codebooks
    frontend: str = "none"          # none | vision_stub | audio_stub
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    schedule: str = "cosine"        # cosine | wsd (minicpm)

    # which attention shapes this arch supports (long_500k needs
    # sub-quadratic state — DESIGN.md §5)
    supports_long_context: bool = False

    def __post_init__(self):
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"pattern length {len(self.layer_pattern)}")
        if self.num_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def mixer_kinds(self) -> Tuple[str, ...]:
        return tuple(p.split(":")[0] for p in self.layer_pattern)

    @property
    def mlp_kinds(self) -> Tuple[str, ...]:
        return tuple(p.split(":")[1] for p in self.layer_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline numbers)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d * max(1, self.codebooks or 1) if self.codebooks \
                else v * d
        if self.codebooks:
            total += (self.codebooks - 1) * v * d  # extra codebook embeds
        for mixer, mlp in zip(self.mixer_kinds, self.mlp_kinds):
            n_pos = self.num_periods
            if mixer == "attn":
                if self.attention == "mla":
                    qk_head = self.qk_nope_head_dim + self.qk_rope_head_dim
                    per = (d * self.q_lora_rank
                           + self.q_lora_rank * self.num_heads * qk_head
                           + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                           + self.kv_lora_rank * self.num_heads
                           * (self.qk_nope_head_dim + self.v_head_dim)
                           + self.num_heads * self.v_head_dim * d)
                else:
                    per = (d * self.num_heads * self.head_dim
                           + 2 * d * self.num_kv_heads * self.head_dim
                           + self.num_heads * self.head_dim * d)
            elif mixer == "mamba":
                d_in = self.mamba_expand * d
                dt_rank = self.mamba_dt_rank or -(-d // 16)
                per = (d * 2 * d_in + self.mamba_d_conv * d_in
                       + d_in * (dt_rank + 2 * self.mamba_d_state)
                       + dt_rank * d_in + d_in * self.mamba_d_state
                       + d_in + d_in * d)
            else:  # rwkv6: 5 tm mats + cm_wr + channel mix + shift/decay loras
                per = (6 * d * d + 2 * d * ff
                       + d * (5 * 32) + 5 * 32 * d       # maa lora
                       + 2 * d * 64)                     # decay lora
            if mlp == "dense":
                per += 3 * d * ff if mixer != "rwkv6" else 0
            elif mlp == "moe":
                per += (self.num_experts * 3 * d * self.moe_d_ff
                        + d * self.num_experts)
                if self.shared_expert_d_ff:
                    per += 3 * d * self.shared_expert_d_ff
            total += per * n_pos
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-to experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_positions = sum(1 for k in self.mlp_kinds if k == "moe")
        all_experts = (moe_positions * self.num_periods
                       * self.num_experts * 3 * self.d_model * self.moe_d_ff)
        active = (moe_positions * self.num_periods
                  * self.num_experts_per_tok * 3 * self.d_model
                  * self.moe_d_ff)
        return full - all_experts + active


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_for(arch: "ArchConfig", shape_name: str) -> ShapeSpec:
    spec = SHAPES[shape_name]
    if shape_name == "long_500k" and not arch.supports_long_context:
        raise ValueError(
            f"{arch.name} is pure full-attention; long_500k is skipped "
            "(DESIGN.md §5)")
    return spec


_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    # import side-effect registration
    from . import _load_all  # noqa
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    from . import _load_all  # noqa
    _load_all()
    return sorted(_REGISTRY)
