"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

28L, d_model 3584, 28 heads (GQA kv=4), d_ff 18944, vocab 152064.
M-RoPE with (temporal, height, width) half-dim sections (16, 24, 24);
dynamic-resolution vision tower is a stub: ``input_specs`` supplies
precomputed patch embeddings + 3-plane position ids (assignment brief).
Qwen2 uses QKV biases.
"""

from .base import ArchConfig, register


@register("qwen2-vl-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attn_bias=True,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),   # sums to head_dim/2 = 64
        frontend="vision_stub",
        tie_embeddings=False,
    )
