"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16 heads (kv=16 — MHA), vocab 151936.
MoE every layer: 60 routed experts top-4 with per-expert d_ff 1408, plus a
shared expert (d_ff 5632, the "4 shared" merged into one wide always-on
expert of equal FLOPs — 4 x 1408 = 5632).
"""

from .base import ArchConfig, register


@register("qwen2-moe-a2.7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5632,                  # dense-equivalent (shared expert width)
        vocab_size=151936,
        attn_bias=True,
        rope_theta=1e6,
        layer_pattern=("attn:moe",),
        num_experts=60,
        num_experts_per_tok=4,
        moe_d_ff=1408,
        shared_expert_d_ff=5632,
    )
