"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M].

32L, d_model 960, 15 heads (GQA kv=5), d_ff 2560, vocab 49152 — llama-style
small model; the end-to-end training example uses a reduced variant of this
family.
"""

from .base import ArchConfig, register


@register("smollm-360m")
def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=1e4,
        tie_embeddings=True,
    )
