"""Jamba-1.5-Large 398B [arXiv:2403.19887 / 2408.12570].

72L, d_model 8192, 64 heads (GQA kv=8), vocab 65536; hybrid Mamba+attention
at 1:7 per 8-layer period (attention at period position 4), MoE 16 experts
top-2 (d_ff 24576) on every other layer (odd positions).  Mamba: d_state 16,
d_conv 4, expand 2.

Supports long_500k: SSM state is O(1) in sequence length and only 9 of 72
layers hold KV caches.
"""

from .base import ArchConfig, register

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba") + ":" + ("moe" if i % 2 == 1 else "dense")
    for i in range(8)
)


@register("jamba-1.5-large-398b")
def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        rope_theta=1e4,
        layer_pattern=_PATTERN,
        num_experts=16,
        num_experts_per_tok=2,
        moe_d_ff=24576,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        supports_long_context=True,
    )
