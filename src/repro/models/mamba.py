"""Mamba (selective SSM) mixer block — the state-space half of Jamba.

Training/prefill runs the selective scan as a *chunked* associative scan:
``lax.scan`` over time chunks (sequential, O(S/chunk) steps) with a
``lax.associative_scan`` inside each chunk — peak memory O(B·chunk·D·N)
instead of O(B·S·D·N), which is what makes jamba-scale models (d_inner 16k,
S up to 512k) lowerable.  Decode is the O(1) recurrent update on a carried
(conv_state, ssm_state) cache.

The recurrence (diagonal A):
    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + Δ_t ⊙ B_t · x_t
    y_t = C_t · h_t + D ⊙ x_t
composed associatively via (a, b) pairs: (a2·a1, a2·b1 + b2).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import _normal

Params = Dict[str, jnp.ndarray]

__all__ = ["init_mamba", "mamba", "init_mamba_cache"]


def _dt_rank(cfg: ArchConfig) -> int:
    return cfg.mamba_dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    din = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A (negative reals), stored as log
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (din, n))
    return {
        "in_proj": _normal(ks[0], (d, 2 * din), dtype),
        "conv_w": _normal(ks[1], (cfg.mamba_d_conv, din), dtype, scale=0.1),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": _normal(ks[2], (din, r + 2 * n), dtype),
        "dt_proj_w": _normal(ks[3], (r, din), dtype, scale=r ** -0.5),
        "dt_proj_b": jnp.full((din,), -4.6, dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(jnp.float32),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": _normal(ks[4], (din, d), dtype),
    }


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    din = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, din), dtype),
        "ssm": jnp.zeros((batch, din, cfg.mamba_d_state), jnp.float32),
    }


def _ssm_scan_chunked(da, db, chunk: int):
    """Associative scan of h_t = da_t ⊙ h_{t-1} + db_t over axis 1.

    da/db: (B, S, D, N) f32.  Returns h (B, S, D, N).
    """
    B, S, D, N = da.shape
    S_pad = -(-S // chunk) * chunk
    if S_pad != S:
        da = jnp.pad(da, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)),
                     constant_values=1.0)
        db = jnp.pad(db, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    nc = S_pad // chunk
    da = da.reshape(B, nc, chunk, D, N).swapaxes(0, 1)   # (nc, B, c, D, N)
    db = db.reshape(B, nc, chunk, D, N).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, xs):
        a_c, b_c = xs
        # prepend carry as an extra element via b' = a_0·h + b_0 on elem 0
        b_c = b_c.at[:, 0].add(a_c[:, 0] * h)
        aa, bb = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        return bb[:, -1], bb

    _, hs = jax.lax.scan(step, jnp.zeros((B, D, N), da.dtype), (da, db))
    hs = hs.swapaxes(0, 1).reshape(B, S_pad, D, N)
    return hs[:, :S]


def mamba(
    p: Params, cfg: ArchConfig, x: jnp.ndarray,
    cache: Optional[Dict] = None, *, chunk: int = 256,
    constrain=lambda t, kind: t,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B, S, D) -> (y (B, S, D), new_cache)."""
    B, S, D = x.shape
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    dconv = cfg.mamba_d_conv

    xz = x @ p["in_proj"]                       # (B, S, 2*din)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "mamba_inner")

    # causal depthwise conv
    if cache is None:
        conv_in = jnp.pad(xs, ((0, 0), (dconv - 1, 0), (0, 0)))
        new_conv = None
    else:
        conv_in = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], 1)
        new_conv = conv_in[:, -(dconv - 1):]
    xc = sum(
        conv_in[:, i:i + S] * p["conv_w"][i] for i in range(dconv)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)

    proj = xc @ p["x_proj"]                     # (B, S, r+2n)
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj_w"]
                         + p["dt_proj_b"]).astype(jnp.float32)  # (B,S,din)
    bmat = proj[..., r:r + n].astype(jnp.float32)               # (B,S,n)
    cmat = proj[..., r + n:].astype(jnp.float32)                # (B,S,n)

    a = -jnp.exp(p["a_log"])                    # (din, n)
    xf = xc.astype(jnp.float32)
    da = jnp.exp(dt[..., None] * a)             # (B,S,din,n)
    db = (dt * xf)[..., None] * bmat[:, :, None, :]

    if cache is None or S > 1:
        h = _ssm_scan_chunked(da, db, chunk)    # (B,S,din,n)
        new_ssm = h[:, -1] if cache is not None else None
    else:
        h = (da[:, 0] * cache["ssm"] + db[:, 0])[:, None]
        new_ssm = h[:, 0]

    y = jnp.einsum("bsdn,bsn->bsd", h, cmat) + xf * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        if new_conv is None:
            new_conv = jnp.pad(xs, ((0, 0), (dconv - 1, 0), (0, 0)))[:, -(dconv - 1):]
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache
