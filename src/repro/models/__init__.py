"""Model zoo: composable backbone built from the arch config's layer
pattern (GQA/MLA attention, dense/MoE MLPs, Mamba, RWKV6, multimodal
frontend stubs)."""

from .model import forward, init_cache, init_params, loss_fn, param_count

__all__ = ["init_params", "forward", "init_cache", "loss_fn", "param_count"]
