"""RWKV-6 (Finch) block: data-dependent-decay linear attention.

Time mixing implements the WKV6 recurrence per 64-wide head

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

with data-dependent w_t (token-shift + LoRA).  Two execution paths:

* **chunked parallel** (train/prefill): within a chunk the pairwise decay
  factor exp(Λ_{t-1} - Λ_s), s ≤ t-1, is ≤ 1 — numerically stable without
  log-space gymnastics; cross-chunk state is carried by ``lax.scan`` (so the
  backward pass checkpoints only chunk boundaries: O(S/c) state memory, the
  property that makes 500k-token contexts feasible).
* **recurrent** (decode): O(1) per token on a carried (shift, state) cache.

Channel mixing is the standard RWKV squared-ReLU gated FFN with token shift.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import _normal, rms_norm

Params = Dict[str, jnp.ndarray]

__all__ = ["init_rwkv_block", "rwkv_block", "init_rwkv_cache"]

_LORA = 32          # token-shift mixer LoRA dim
_DECAY_LORA = 64


def init_rwkv_block(key, cfg: ArchConfig, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    hs = cfg.rwkv_head_size
    ks = jax.random.split(key, 14)
    return {
        # time mixing
        "maa_x": jnp.zeros((d,), dtype),
        "maa_rkvwg": jnp.zeros((5, d), dtype),
        "maa_w1": _normal(ks[0], (d, 5 * _LORA), dtype),
        "maa_w2": _normal(ks[1], (5, _LORA, d), dtype),
        "decay": jnp.full((d,), -4.0, jnp.float32),
        "decay_w1": _normal(ks[2], (d, _DECAY_LORA), dtype),
        "decay_w2": _normal(ks[3], (_DECAY_LORA, d), dtype),
        "bonus": jnp.zeros((d // hs, hs), jnp.float32),      # u, per head
        "wr": _normal(ks[4], (d, d), dtype),
        "wk": _normal(ks[5], (d, d), dtype),
        "wv": _normal(ks[6], (d, d), dtype),
        "wg": _normal(ks[7], (d, d), dtype),
        "wo": _normal(ks[8], (d, d), dtype),
        "ln_x": jnp.ones((d,), dtype),
        # channel mixing
        "cm_maa_k": jnp.zeros((d,), dtype),
        "cm_maa_r": jnp.zeros((d,), dtype),
        "cm_wk": _normal(ks[9], (d, ff), dtype),
        "cm_wv": _normal(ks[10], (ff, d), dtype),
        "cm_wr": _normal(ks[11], (d, d), dtype),
        # per-block norms (RWKV uses two lns before tm/cm)
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
    }


def init_rwkv_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "state": jnp.zeros((batch, d // hs, hs, hs), jnp.float32),
    }


def _token_shift(x: jnp.ndarray, shift_state: Optional[jnp.ndarray]):
    """x (B,S,D) -> x_{t-1} (B,S,D); position 0 uses the cache (or zeros)."""
    prev = jnp.zeros_like(x[:, :1]) if shift_state is None \
        else shift_state[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, w, u, chunk: int):
    """WKV6 over full sequences.  r/k/v (B,S,H,hs); w (B,S,H,hs) in (0,1);
    u (H,hs).  Returns y (B,S,H,hs), final state (B,H,hs,hs)."""
    B, S, H, hs = r.shape
    c = min(chunk, S)
    S_pad = -(-S // c) * c
    pad = ((0, 0), (0, S_pad - S), (0, 0), (0, 0))
    rf = jnp.pad(r.astype(jnp.float32), pad)
    kf = jnp.pad(k.astype(jnp.float32), pad)
    vf = jnp.pad(v.astype(jnp.float32), pad)
    wf = jnp.pad(w.astype(jnp.float32), pad, constant_values=1.0)
    nc = S_pad // c

    def resh(t):  # (B, S, H, hs) -> (nc, B, H, c, hs)
        return t.reshape(B, nc, c, H, hs).transpose(1, 0, 3, 2, 4)

    rf, kf, vf, wf = map(resh, (rf, kf, vf, wf))
    logw = jnp.log(jnp.maximum(wf, 1e-38))                 # (nc,B,H,c,hs)
    lam = jnp.cumsum(logw, axis=3)                         # Λ_t (inclusive)

    tri_low = jnp.tril(jnp.ones((c, c), jnp.float32), -1)  # s < t

    def step(state, xs):
        rr, kk, vv, ll, lw = xs           # blocks (B,H,c,hs) ; state (B,H,hs,hs)
        lam_prev = ll - lw                # Λ_{t-1}
        # pairwise stable decay exp(Λ_{t-1} - Λ_s) for s<t  (≤ 1)
        e = jnp.exp(jnp.minimum(
            lam_prev[:, :, :, None, :] - ll[:, :, None, :, :], 0.0))
        a = jnp.einsum("bhti,bhtsi,bhsi->bhts", rr, e, kk)
        a = a * tri_low
        # diagonal bonus term  r_t·(u ⊙ k_t)
        diag = (rr * kk * u[None, :, None, :]).sum(-1)     # (B,H,c)
        y = jnp.einsum("bhts,bhsj->bhtj", a, vv)
        y = y + diag[..., None] * vv
        # contribution of the inbound state
        y = y + jnp.einsum("bhti,bhij->bhtj", rr * jnp.exp(lam_prev), state)
        # state update: S' = diag(exp(Λ_c)) S + Σ_s exp(Λ_c - Λ_s) k_s v_sᵀ
        decay_all = jnp.exp(ll[:, :, -1, :])               # (B,H,hs)
        carry_k = kk * jnp.exp(ll[:, :, -1:, :] - ll)      # ≤ 1 factors
        state = state * decay_all[..., None] + jnp.einsum(
            "bhsi,bhsj->bhij", carry_k, vv)
        return state, y

    state0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    state, ys = jax.lax.scan(step, state0, (rf, kf, vf, lam, logw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S_pad, H, hs)[:, :S]
    return y, state


def _wkv_recurrent(r, k, v, w, u, state):
    """One decode step.  r/k/v/w (B,1,H,hs); state (B,H,hs,hs) f32."""
    rf, kf, vf, wf = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    at = kf[..., :, None] * vf[..., None, :]               # (B,H,hs,hs)
    y = jnp.einsum("bhi,bhij->bhj", rf, state + u[..., None] * at)
    state = state * wf[..., None] + at
    return y[:, None], state


def rwkv_block(
    p: Params, cfg: ArchConfig, x: jnp.ndarray,
    cache: Optional[Dict] = None, *, chunk: int = 64,
    constrain=lambda t, kind: t,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full RWKV6 block (time mix + channel mix).  x (B,S,D)."""
    B, S, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    eps = cfg.norm_eps

    # ---- time mixing ----
    xn = rms_norm({"scale": p["ln1"]}, x, eps)
    prev = _token_shift(xn, cache["tm_shift"] if cache else None)
    xx = prev - xn
    mix = xn + xx * p["maa_x"]
    lora = jnp.tanh(mix @ p["maa_w1"]).reshape(B, S, 5, _LORA)
    deltas = jnp.einsum("bsfl,fld->fbsd", lora, p["maa_w2"])
    xr, xk, xv, xw, xg = (
        xn + xx * (p["maa_rkvwg"][i] + deltas[i]) for i in range(5))

    r = (xr @ p["wr"]).reshape(B, S, H, hs)
    k = (xk @ p["wk"]).reshape(B, S, H, hs)
    v = (xv @ p["wv"]).reshape(B, S, H, hs)
    g = jax.nn.silu(xg @ p["wg"])
    r = constrain(r, "heads")

    dlog = (p["decay"]
            + (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dlog)).reshape(B, S, H, hs)       # ∈ (0,1)

    if cache is None:
        y, _ = _wkv_chunked(r, k, v, w, p["bonus"], chunk)
        new_cache = None
    elif S == 1:
        y, state = _wkv_recurrent(r, k, v, w, p["bonus"], cache["state"])
        new_cache = {"state": state, "tm_shift": xn[:, -1],
                     "cm_shift": None}   # filled below
    else:  # prefill with cache
        y, state = _wkv_chunked(r, k, v, w, p["bonus"], chunk)
        new_cache = {"state": state, "tm_shift": xn[:, -1],
                     "cm_shift": None}

    y = y.reshape(B, S, D).astype(x.dtype)
    y = rms_norm({"scale": p["ln_x"]}, y, eps) * g
    x = x + y @ p["wo"]

    # ---- channel mixing ----
    xn2 = rms_norm({"scale": p["ln2"]}, x, eps)
    prev2 = _token_shift(xn2, cache["cm_shift"] if cache else None)
    xx2 = prev2 - xn2
    xk2 = xn2 + xx2 * p["cm_maa_k"]
    xr2 = xn2 + xx2 * p["cm_maa_r"]
    kk = jnp.square(jax.nn.relu(xk2 @ p["cm_wk"]))
    out = x + jax.nn.sigmoid(xr2 @ p["cm_wr"]) * (kk @ p["cm_wv"])

    if new_cache is not None:
        new_cache["cm_shift"] = xn2[:, -1]
    return out, new_cache
