"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

GShard/MaxText-style dense dispatch: routing builds a one-hot dispatch
tensor (tokens × experts × capacity); expert FFNs run as one batched einsum
over the expert dimension, which shards cleanly (EP over whichever mesh axis
divides ``num_experts``, expert-TP otherwise — sharding/specs.py decides).
Tokens over capacity are dropped (contribute zero) and counted in the aux
outputs; the load-balance auxiliary loss follows Switch/GShard.

Scalability note (DESIGN.md §6): the dispatch/combine one-hots are
O(T²·k·cf/E) in token count T — quadratic.  ``moe_layer`` therefore
processes tokens in fixed-size chunks under ``lax.scan``: dispatch memory is
bounded by one chunk (default 4096 tokens) regardless of sequence length,
which is what lets 32k-token prefill and large local batches lower.  The
capacity rule applies per chunk.

An always-on shared expert (Qwen2-MoE) runs as a plain dense MLP beside the
routed experts.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import _normal, init_mlp, mlp

Params = Dict[str, jnp.ndarray]

__all__ = ["init_moe", "moe_layer"]


def _padded_experts(cfg: ArchConfig) -> int:
    e, m = cfg.num_experts, cfg.expert_pad_multiple
    return e if m <= 0 else -(-e // m) * m


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    d, ff = cfg.d_model, cfg.moe_d_ff
    e = _padded_experts(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": _normal(ks[0], (d, cfg.num_experts), dtype, scale=0.02),
        "wg": _normal(ks[1], (e, d, ff), dtype),
        "wu": _normal(ks[2], (e, d, ff), dtype),
        "wd": _normal(ks[3], (e, ff, d), dtype),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = init_mlp(ks[4], d, cfg.shared_expert_d_ff, dtype,
                               cfg.mlp_act)
    return p


def _route_chunk(p, cfg: ArchConfig, xt: jnp.ndarray, C: int, constrain):
    """Dispatch/compute/combine for one token chunk.  xt (T, D)."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    E_pad = _padded_experts(cfg)   # padded experts receive no tokens

    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E_pad, dtype=jnp.int32)  # (T, K, Ep)
    E = E_pad
    flat = onehot.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = (pos * flat).sum(-1).reshape(T, K)                 # (T, K)
    keep = pos < C
    dropped = 1.0 - keep.mean()

    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=jnp.float32)[..., :C]        # (T, K, C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot.astype(jnp.float32),
                          slot).astype(xt.dtype)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot.astype(jnp.float32),
                         slot, gate_vals).astype(xt.dtype)

    xin = jnp.einsum("tec,td->ecd", dispatch, xt)            # (E, C, D)
    xin = constrain(xin, "expert_in").astype(xt.dtype)
    # bf16 operands + f32 accumulation: keeps the (big) expert weights in
    # their storage dtype — no f32 upcast copies/all-gathers of weights
    hg = jnp.einsum("ecd,edf->ecf", xin, p["wg"],
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("ecd,edf->ecf", xin, p["wu"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hu).astype(xt.dtype)
    xout = jnp.einsum("ecf,efd->ecd", h, p["wd"],
                      preferred_element_type=jnp.float32)    # (E, C, D)
    xout = constrain(xout, "expert_in").astype(xt.dtype)
    out = jnp.einsum("tec,ecd->td", combine, xout)

    f = jnp.mean(onehot.astype(jnp.float32).sum(1), axis=0)
    lb_loss = cfg.num_experts * jnp.sum(
        f[:cfg.num_experts] * probs.mean(0))
    return out, lb_loss, dropped.astype(jnp.float32)


def moe_layer(
    p: Params, cfg: ArchConfig, x: jnp.ndarray,
    constrain=lambda t, kind: t, exact: bool = False,
    token_chunk: int = 4096,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x (B, S, D) -> (out (B, S, D), aux {load_balance_loss, drop_frac}).

    Capacity C = ceil(Tc/E · k · capacity_factor) per chunk of Tc tokens.
    ``exact=True`` (decode) uses C = Tc: no token is ever dropped, so decode
    logits agree with teacher forcing.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xt = x.reshape(T, D)

    Tc = min(token_chunk, T)
    n_chunks = -(-T // Tc)
    C = Tc if exact else max(1, int(Tc * K * cfg.capacity_factor / E + 0.999))
    C = min(C, Tc)

    if n_chunks == 1:
        out, lb, drop = _route_chunk(p, cfg, xt, C, constrain)
    else:
        pad = n_chunks * Tc - T
        xp = jnp.pad(xt, ((0, pad), (0, 0)))
        chunks = xp.reshape(n_chunks, Tc, D)
        # Re-pin the token sharding onto the *within-chunk* dim: without
        # this the chunk axis inherits the data sharding and the SPMD
        # partitioner replicates the whole dispatch pipeline per device
        # (measured 16x bytes+flops blowup, EXPERIMENTS.md §Perf cell A).
        chunks = constrain(chunks, "moe_chunks")

        # checkpoint: recompute the O(Tc·E·C) dispatch/combine tensors in
        # the backward instead of stacking them across chunks.
        @jax.checkpoint
        def body_fn(xc):
            return _route_chunk(p, cfg, xc, C, constrain)

        def body(_, xc):
            out, lb, drop = body_fn(xc)
            return (), (out, lb, drop)

        _, (outs, lbs, drops) = jax.lax.scan(body, (), chunks)
        out = outs.reshape(n_chunks * Tc, D)[:T]
        out = constrain(out, "moe_tokens")
        lb, drop = lbs.mean(), drops.mean()

    if "shared" in p:
        out = out + mlp(p["shared"], xt, cfg.mlp_act)

    aux = {"load_balance_loss": lb, "drop_frac": drop}
    return out.reshape(B, S, D), aux
