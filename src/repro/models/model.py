"""Public model API: embeddings + stack + head, train/prefill/decode entry
points, and the multimodal frontend stubs.

Batch dict conventions (shapes global; launchers shard them):

* ``tokens``          (B, S) int32, or (B, codebooks, S) for musicgen
* ``positions``       (B, S) int32, or (3, B, S) for M-RoPE (qwen2-vl)
* ``frontend_embeds`` (B, S, D) optional — precomputed patch/frame
                      embeddings (the modality frontend is a stub per the
                      assignment brief); substituted where ``embed_mask``
* ``embed_mask``      (B, S) bool optional
* ``labels``          like tokens (train)
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import _normal, init_rms_norm, rms_norm
from .transformer import apply_stack, init_stack, init_stack_cache

Params = Dict[str, Any]
Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]

__all__ = ["init_params", "forward", "init_cache", "loss_fn",
           "param_count"]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_params(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    k_embed, k_stack, k_head = jax.random.split(key, 3)
    ncb = max(1, cfg.codebooks)
    p: Params = {
        "embed": _normal(k_embed, (ncb, cfg.vocab_size, cfg.d_model), dt)
        if cfg.codebooks else _normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                      dt),
        "stack": init_stack(k_stack, cfg),
        "ln_f": init_rms_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = (
            _normal(k_head, (ncb, cfg.d_model, cfg.vocab_size), dt)
            if cfg.codebooks
            else _normal(k_head, (cfg.d_model, cfg.vocab_size), dt))
    return p


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return init_stack_cache(cfg, batch, max_len)


def _embed(params, cfg: ArchConfig, batch, constrain: Constrain):
    tokens = batch["tokens"]
    if cfg.codebooks:
        # (B, C, S): sum codebook embeddings (EnCodec parallel streams)
        x = jax.vmap(
            lambda table, toks: jnp.take(table, toks, axis=0),
            in_axes=(0, 1), out_axes=0,
        )(params["embed"], tokens)                      # (C, B, S, D)
        x = x.sum(axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)   # (B, S, D)
    if "frontend_embeds" in batch:
        mask = batch["embed_mask"][..., None]
        x = jnp.where(mask, batch["frontend_embeds"].astype(x.dtype), x)
    return constrain(x, "hidden")


def _head(params, cfg: ArchConfig, x, constrain: Constrain):
    if cfg.codebooks:
        logits = jnp.einsum("bsd,cdv->bcsv", x, params["head"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["head"]
    return constrain(logits, "logits")


def forward(
    params: Params, cfg: ArchConfig, batch: Dict, *,
    cache=None, mode: str = "train", attn_impl: str = "xla",
    constrain: Constrain = lambda t, k: t, remat: str = "full",
    logits_slice: Optional[str] = None,
):
    """mode: train (no cache) | prefill | decode.

    ``logits_slice='last'`` returns logits only for the final position
    (serving: avoids materializing (B, S, V)).
    Returns (logits, new_cache, aux).
    """
    x = _embed(params, cfg, batch, constrain)
    positions = batch["positions"]
    x, new_cache, aux = apply_stack(
        params["stack"], cfg, x, positions, cache,
        attn_impl=attn_impl, constrain=constrain,
        remat=remat if mode == "train" else "none")
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    logits = _head(params, cfg, x, constrain)
    return logits, new_cache, aux


def loss_fn(
    params: Params, cfg: ArchConfig, batch: Dict, *,
    attn_impl: str = "xla", constrain: Constrain = lambda t, k: t,
    remat: str = "full", aux_loss_weight: float = 0.01,
):
    """Next-token cross-entropy (+ MoE load-balance aux).  Returns
    (loss, metrics)."""
    logits, _, aux = forward(params, cfg, batch, mode="train",
                             attn_impl=attn_impl, constrain=constrain,
                             remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = ce + aux_loss_weight * aux["load_balance_loss"]
    return loss, {"ce": ce, **aux}


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
