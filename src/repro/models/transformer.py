"""Backbone assembly: periodic layer stack scanned over repeats.

A stack is ``num_periods`` repetitions of ``cfg.layer_pattern`` (e.g. dense
LM: 1-layer period ``("attn:dense",)``; Jamba: 8-layer period with one
attention position and MoE on odd positions).  Parameters and caches for
each period-position are stacked along a leading axis and the stack is
``lax.scan``-ed — one compiled period body regardless of depth, which keeps
dry-run compiles tractable and HLO small.

Mixers: GQA attention, MLA, Mamba, RWKV6 (RWKV owns its whole block incl.
channel-mix, mlp kind "none").  MLPs: dense SwiGLU/GELU, MoE (+shared).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from . import layers as L
from .mamba import init_mamba, init_mamba_cache, mamba
from .moe import init_moe, moe_layer
from .rwkv import init_rwkv_block, init_rwkv_cache, rwkv_block

Params = Dict[str, Any]
Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]

__all__ = ["init_stack", "apply_stack", "init_stack_cache"]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# per-position init
# --------------------------------------------------------------------------

def _init_position(key, cfg: ArchConfig, kind: str) -> Params:
    mixer, mlp_kind = kind.split(":")
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {}
    if mixer == "attn":
        p["ln_attn"] = L.init_rms_norm(cfg.d_model, dt)
        p["attn"] = (L.init_mla(ks[0], cfg, dt) if cfg.attention == "mla"
                     else L.init_attention(ks[0], cfg, dt))
    elif mixer == "mamba":
        p["ln_attn"] = L.init_rms_norm(cfg.d_model, dt)
        p["mamba"] = init_mamba(ks[0], cfg, dt)
    elif mixer == "rwkv6":
        p["rwkv"] = init_rwkv_block(ks[0], cfg, dt)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    if mlp_kind == "dense":
        p["ln_mlp"] = L.init_rms_norm(cfg.d_model, dt)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.mlp_act)
    elif mlp_kind == "moe":
        p["ln_mlp"] = L.init_rms_norm(cfg.d_model, dt)
        p["moe"] = init_moe(ks[1], cfg, dt)
    elif mlp_kind != "none":
        raise ValueError(f"unknown mlp kind {mlp_kind!r}")
    return p


def _position_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    mixer, _ = kind.split(":")
    dt = _dtype(cfg)
    if mixer == "attn":
        if cfg.attention == "mla":
            return {
                "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros(
                    (batch, max_len, 1, cfg.qk_rope_head_dim), dt),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dt),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if mixer == "mamba":
        return init_mamba_cache(cfg, batch, dt)
    if mixer == "rwkv6":
        return init_rwkv_cache(cfg, batch, dt)
    raise ValueError(mixer)


def _apply_position(
    p: Params, cfg: ArchConfig, kind: str, x, positions, cache,
    attn_impl: str, constrain: Constrain,
):
    """One layer.  Returns (x, new_cache, aux)."""
    mixer, mlp_kind = kind.split(":")
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "drop_frac": jnp.zeros((), jnp.float32)}
    new_cache = cache

    if mixer == "rwkv6":
        x, new_cache = rwkv_block(p["rwkv"], cfg, x, cache,
                                  constrain=constrain)
        x = constrain(x, "hidden")
        return x, new_cache, aux

    h = L.rms_norm(p["ln_attn"], x, cfg.norm_eps)
    if mixer == "attn":
        fn = L.mla if cfg.attention == "mla" else L.attention
        mix_out, new_cache = fn(p["attn"], cfg, h, positions, cache,
                                attn_impl=attn_impl, constrain=constrain)
    else:
        mix_out, new_cache = mamba(p["mamba"], cfg, h, cache,
                                   constrain=constrain)

    if cfg.parallel_block and mlp_kind != "none":
        # command-r style: attn and mlp both read the same normed input
        if mlp_kind == "dense":
            mlp_out = L.mlp(p["mlp"], h, cfg.mlp_act)
        else:
            mlp_out, aux = moe_layer(p["moe"], cfg, h, constrain=constrain,
                                     exact=cache is not None and x.shape[1] == 1)
        x = x + mix_out + mlp_out
        x = constrain(x, "hidden")
        return x, new_cache, aux

    x = x + mix_out
    x = constrain(x, "hidden")
    if mlp_kind != "none":
        h2 = L.rms_norm(p["ln_mlp"], x, cfg.norm_eps)
        if mlp_kind == "dense":
            x = x + L.mlp(p["mlp"], h2, cfg.mlp_act)
        else:
            out, aux = moe_layer(p["moe"], cfg, h2, constrain=constrain,
                                 exact=cache is not None and x.shape[1] == 1)
            x = x + out
        x = constrain(x, "hidden")
    return x, new_cache, aux


# --------------------------------------------------------------------------
# stack = scan over periods
# --------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig) -> Params:
    """Stacked (leading axis = num_periods) params for every pattern
    position."""
    out: Params = {}
    for pos, kind in enumerate(cfg.layer_pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos),
                                cfg.num_periods)
        out[f"pos{pos}"] = jax.vmap(
            lambda k: _init_position(k, cfg, kind))(keys)
    return out


def init_stack_cache(cfg: ArchConfig, batch: int, max_len: int):
    out = {}
    for pos, kind in enumerate(cfg.layer_pattern):
        one = _position_cache(cfg, kind, batch, max_len)
        out[f"pos{pos}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.num_periods,) + a.shape).copy(), one)
    return out


def apply_stack(
    params: Params, cfg: ArchConfig, x: jnp.ndarray, positions,
    cache=None, *, attn_impl: str = "xla",
    constrain: Constrain = lambda t, k: t,
    remat: str = "full",
):
    """Run the whole stack.  Returns (x, new_cache, aux_means)."""
    pattern = cfg.layer_pattern

    def period_body(carry, xs):
        x = carry
        p_params, p_cache = xs
        new_caches = {}
        auxes = []
        for pos, kind in enumerate(pattern):
            c = None if p_cache is None else p_cache[f"pos{pos}"]
            x, nc, aux = _apply_position(
                p_params[f"pos{pos}"], cfg, kind, x, positions, c,
                attn_impl, constrain)
            new_caches[f"pos{pos}"] = nc if nc is not None else c
            auxes.append(aux)
        aux = jax.tree.map(lambda *a: jnp.stack(a).mean(), *auxes)
        return x, (new_caches, aux)

    body = period_body
    if remat == "full":
        body = jax.checkpoint(period_body,
                              prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if cache is None:
        xs = (params, None)
        # scan requires every xs leaf to have the period leading axis; params
        # do, and `None` cache is threaded statically.
        x, (_, aux) = jax.lax.scan(
            lambda c, pp: body(c, (pp, None)), x, params)
    else:
        x, (new_cache, aux) = jax.lax.scan(body, x, (params, cache))
        return x, new_cache, jax.tree.map(jnp.mean, aux)
    return x, None, jax.tree.map(jnp.mean, aux)
