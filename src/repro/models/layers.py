"""Core neural layers: norms, embeddings, RoPE/M-RoPE, GQA and MLA
attention, SwiGLU/GELU MLPs.

Functional style: ``init_*`` builds a param dict, ``apply``-style functions
are pure.  Sharding is applied by the caller (sharding/specs.py maps param
paths to PartitionSpecs; activation constraints are inserted in
transformer.py).  All matmuls run in the config dtype (bf16 by default) with
f32 accumulation via ``preferred_element_type`` where it matters.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_attn import attention_ref, flash_attention
from repro.kernels.flash_attn.chunked import chunked_attention

__all__ = [
    "rms_norm", "init_rms_norm", "init_dense", "dense",
    "rope", "mrope", "init_attention", "attention",
    "init_mla", "mla", "init_mlp", "mlp",
]

Params = Dict[str, jnp.ndarray]


# -- initializers ------------------------------------------------------------

def _normal(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    p = {"w": _normal(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- rotary position embeddings ----------------------------------------------

def _rope_angles(positions: jnp.ndarray, half_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, half_dim), f32."""
    freqs = theta ** (-jnp.arange(0, half_dim, dtype=jnp.float32) / half_dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard rotary embedding.  x (B, S, H, D), positions (B, S)."""
    half = x.shape[-1] // 2
    cos, sin = _rope_angles(positions, half, theta)   # (B, S, half)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
          sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    ``positions`` (3, B, S) carries (temporal, height, width) ids; the
    rotary half-dim is split into ``sections`` (summing to D/2), section i
    rotating with positions[i].  Text tokens carry identical ids in all
    three planes, reducing exactly to standard RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_parts = []
    start = 0
    for i, sec in enumerate(sections):
        ang_parts.append(
            positions[i].astype(jnp.float32)[..., None] * freqs[start:start + sec])
        start += sec
    ang = jnp.concatenate(ang_parts, -1)              # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _apply_rope(cfg: ArchConfig, x: jnp.ndarray, positions) -> jnp.ndarray:
    if cfg.mrope_sections:
        if positions.ndim == 2:   # plain text positions -> broadcast to 3
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return rope(x, positions, cfg.rope_theta)


# -- grouped-query attention ---------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _normal(ks[0], (d, h, hd), dtype),
        "wk": _normal(ks[1], (d, hk, hd), dtype),
        "wv": _normal(ks[2], (d, hk, hd), dtype),
        "wo": _normal(ks[3], (h, hd, d), dtype),
        **({"bq": jnp.zeros((h, hd), dtype),
            "bk": jnp.zeros((hk, hd), dtype),
            "bv": jnp.zeros((hk, hd), dtype)} if cfg.attn_bias else {}),
    }


def attention(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, positions,
    cache: Optional[Dict] = None, *, attn_impl: str = "xla",
    constrain=lambda t, kind: t,
):
    """GQA attention.  x (B, S, D).

    ``cache``: None for training;
    {"k": (B, Smax, Hk, hd), "v": ..., "len": (B,)} for serving — prefill
    writes positions [0, S), decode appends at ``len``.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q, "heads")
    q = _apply_rope(cfg, q, positions)
    k = _apply_rope(cfg, k, positions)

    new_cache = None
    if cache is None:
        qh = jnp.swapaxes(q, 1, 2)     # (B, H, S, hd)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        if attn_impl == "pallas":
            out = flash_attention(qh, kh, vh, causal=True)
        elif attn_impl == "chunked":
            out = chunked_attention(qh, kh, vh, causal=True)
        else:
            out = attention_ref(qh, kh, vh, causal=True)
        out = jnp.swapaxes(out, 1, 2)  # (B, S, H, hd)
    else:
        if S == 1:   # decode: append and attend over the whole cache
            idx = cache["len"]                        # (B,)
            ck = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            )(cache["k"], k, idx)
            cv = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            )(cache["v"], v, idx)
            new_cache = {"k": ck, "v": cv, "len": idx + 1}
            out = _decode_attend(q, ck, cv, idx + 1, constrain)
        else:        # prefill: fill [0, S)
            ck = jnp.zeros_like(cache["k"]).at[:, :S].set(k)
            cv = jnp.zeros_like(cache["v"]).at[:, :S].set(v)
            new_cache = {"k": ck, "v": cv,
                         "len": jnp.full((B,), S, jnp.int32)}
            qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
            if attn_impl == "pallas":
                out = flash_attention(qh, kh, vh, causal=True)
            elif attn_impl == "chunked":
                out = chunked_attention(qh, kh, vh, causal=True)
            else:
                out = attention_ref(qh, kh, vh, causal=True)
            out = jnp.swapaxes(out, 1, 2)
    out = constrain(out, "heads")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _decode_attend(q, ck, cv, kv_len, constrain=lambda t, k: t):
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q (B, 1, H, hd); ck/cv (B, Smax, Hk, hd); kv_len (B,).
    Written as masked logsumexp so XLA can keep the cache sharded along S
    and reduce with partial softmax accumulators (flash-decode); the serve
    path additionally wraps this in shard_map for explicit psum combining.
    """
    B, Smax, Hk, hd = ck.shape
    H = q.shape[2]
    group = H // Hk
    qg = q.reshape(B, 1, Hk, group, hd)
    # bf16 cache operands + f32 accumulation: never materializes an f32
    # copy of the (huge) cache (§Perf cell B, iteration 3)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, ck,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    mask = (jnp.arange(Smax) < kv_len[:, None])[:, None, None, None, :]
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(s - m), 0.0)
    num = jnp.einsum("bhgqs,bshd->bqhgd", e.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    den = jnp.sum(e, axis=-1)[..., None].transpose(0, 3, 1, 2, 4)
    out = num / jnp.maximum(den, 1e-30)
    return out.reshape(B, 1, H, cv.shape[-1]).astype(q.dtype)


# -- multi-head latent attention (MiniCPM3 / DeepSeek-style MLA) -------------

def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": _normal(ks[0], (d, cfg.q_lora_rank), dtype),
        "wuq": _normal(ks[1], (cfg.q_lora_rank, h, qk_head), dtype),
        "wdkv": _normal(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                        dtype),
        "wuk": _normal(ks[3], (cfg.kv_lora_rank, h, cfg.qk_nope_head_dim),
                       dtype),
        "wuv": _normal(ks[4], (cfg.kv_lora_rank, h, cfg.v_head_dim), dtype),
        "wo": _normal(ks[5], (h, cfg.v_head_dim, d), dtype),
        "q_norm": jnp.ones((cfg.q_lora_rank,), dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
    }


def mla(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, positions,
    cache: Optional[Dict] = None, *, attn_impl: str = "xla",
    constrain=lambda t, kind: t,
):
    """MLA: queries/keys split into nope+rope parts; KV compressed into a
    ``kv_lora_rank`` latent (the cache stores latent + shared rope key —
    the memory win that motivates MLA).  Returns (out, new_cache)."""
    B, S, D = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    eps = cfg.norm_eps

    cq = rms_norm({"scale": p["q_norm"]}, x @ p["wdq"], eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = _apply_rope(cfg, q_rope, positions)

    ckv_full = x @ p["wdkv"]                       # (B,S,rank+dr)
    ckv, k_rope = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    ckv = rms_norm({"scale": p["kv_norm"]}, ckv, eps)
    k_rope = _apply_rope(cfg, k_rope[:, :, None, :], positions)  # (B,S,1,dr)

    def expand(ckv, k_rope):
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (dr,))], -1)
        return k, v

    new_cache = None
    if cache is None:
        k, v = expand(ckv, k_rope)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q_full, k, v))
        if attn_impl == "pallas" and dn + dr == dv:
            out = flash_attention(qh, kh, vh, causal=True)
        elif attn_impl == "chunked":
            out = chunked_attention(qh, kh, vh, causal=True)
        else:
            out = attention_ref(qh, kh, vh, causal=True)
        out = jnp.swapaxes(out, 1, 2)
    else:
        if S == 1:
            idx = cache["len"]
            cc = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
            )(cache["ckv"], ckv, idx)
            cr = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            )(cache["k_rope"], k_rope, idx)
            new_cache = {"ckv": cc, "k_rope": cr, "len": idx + 1}
            k, v = expand(cc, cr)
            q_full = jnp.concatenate([q_nope, q_rope], -1)
            out = _decode_attend(q_full, k, v, idx + 1)
        else:
            Smax = cache["ckv"].shape[1]
            cc = jnp.zeros_like(cache["ckv"]).at[:, :S].set(ckv)
            cr = jnp.zeros_like(cache["k_rope"]).at[:, :S].set(k_rope)
            new_cache = {"ckv": cc, "k_rope": cr,
                         "len": jnp.full((B,), S, jnp.int32)}
            k, v = expand(ckv, k_rope)
            q_full = jnp.concatenate([q_nope, q_rope], -1)
            qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q_full, k, v))
            out = attention_ref(qh, kh, vh, causal=True)
            out = jnp.swapaxes(out, 1, 2)
    out = constrain(out, "heads_v")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# -- MLPs ---------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype, act: str = "silu") -> Params:
    ks = jax.random.split(key, 3)
    if act == "silu":   # SwiGLU
        return {"wg": _normal(ks[0], (d, ff), dtype),
                "wu": _normal(ks[1], (d, ff), dtype),
                "wd": _normal(ks[2], (ff, d), dtype)}
    return {"wu": _normal(ks[1], (d, ff), dtype),
            "wd": _normal(ks[2], (ff, d), dtype)}


def mlp(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    if act == "silu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wu"]) @ p["wd"]
