"""Serving substrate: prefill/decode steps with sequence-sharded caches."""

from .serve_step import make_decode_step, make_prefill_step, sample_token

__all__ = ["make_prefill_step", "make_decode_step", "sample_token"]
