"""Serving substrate.

* :mod:`repro.serve.serve_step` — LM prefill/decode steps with
  sequence-sharded caches, plus :func:`make_trace_runner` (the SNP device
  call: single-device or mesh-sharded).
* :mod:`repro.serve.snp_service` — batched SNP trace serving: heterogeneous
  (system, steps, policy, seed) requests padded into fixed-size device
  batches over :func:`repro.core.engine.run_traces`; synchronous
  submit/drain or an async futures mode with a background flush thread
  (DESIGN.md §4).
"""

from .serve_step import (make_decode_step, make_prefill_step,
                         make_trace_runner, sample_token)
from .snp_service import SNPTraceService, TraceRequest, TraceResult

__all__ = ["make_prefill_step", "make_decode_step", "sample_token",
           "make_trace_runner",
           "SNPTraceService", "TraceRequest", "TraceResult"]
