"""Batched SNP trace serving: heterogeneous requests -> padded device batches.

The engine's :func:`~repro.core.engine.run_traces` is the device-side hot
loop (one ``lax.scan``, whole batch through one ``StepBackend.expand`` per
step); this module is the host-side front end that makes it a service.
Architecture notes — batching/bucketing rules, the group key, the async
drain state machine, the failure-domain state machine, and the mesh
sharding layout — live in DESIGN.md §4; the short version:

* **sync mode** (default): :meth:`~SNPTraceService.submit` returns a
  ticket; :meth:`~SNPTraceService.drain` groups compatible requests, pads
  every group to a fixed batch size and step bucket, runs one jitted call
  per padded batch, and returns ``{ticket: TraceResult}``.
* **async mode** (``async_mode=True``): :meth:`submit` returns a
  :class:`concurrent.futures.Future`; a background flush thread fires as
  soon as a group fills a whole batch or the group's oldest request has
  waited ``max_delay_ms``.  Errors raised by a flush propagate into the
  affected futures; :meth:`close` flushes everything still pending and
  joins the thread.
* **failure domains** (``policy=FaultPolicy(...)``): expired-deadline
  requests fail fast with
  :class:`~repro.runtime.faults.DeadlineExceeded` before consuming
  device time; transient flush failures retry with exponential backoff +
  deterministic jitter; exhausted retries walk the encoding-compatible
  backend degrade chain (:mod:`repro.core.failover`), then **bisect the
  chunk** to isolate the poison request — re-running already-good traces
  is free by seed-determinism — so only the culprit's future carries the
  exception; ``max_pending`` admission control rejects at submit.  All
  of it observable through :meth:`stats`.  With ``policy=None`` (the
  default) the historical behavior is preserved exactly: one failure
  fails the whole co-batched flush.

Per-trace PRNG keys mean padding/batching/flush-timing never changes a
trajectory: the result for a request is bit-identical to a solo
:func:`~repro.core.engine.run_trace` with the same seed, and async results
are bit-identical to a synchronous :meth:`drain` of the same requests —
including across retries and bisection.

The device call is pluggable via ``runner`` (a
:func:`~repro.core.engine.run_traces`-compatible callable) so the same
front end drives the single-device path or the mesh-sharded
:func:`~repro.core.distributed.run_traces_distributed`
(:func:`repro.serve.serve_step.make_trace_runner` builds either);
``fault_injector`` (:class:`~repro.runtime.faults.FaultInjector`) wraps
it with a deterministic fault schedule for tests and the ``serve_fault``
bench tier.
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import failover
from repro.core.backend import BackendLike, get_backend, lower_with_backend
from repro.core.engine import run_traces
from repro.core.matrix import CompiledAny, CompiledSparseSNP, is_compiled
from repro.core.plan import SystemPlan
from repro.core.system import SNPSystem
from repro.runtime.faults import (AdmissionRejected, DeadlineExceeded,
                                  FaultInjector, FaultPolicy, InjectedFault)

__all__ = ["TraceRequest", "TraceResult", "SNPTraceService"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class TraceRequest:
    """One trajectory request: which system, how long, how to branch.

    ``deadline_ms`` (serving under a :class:`FaultPolicy` only) bounds
    how long the request may wait before its device call: an expired
    request fails fast with DeadlineExceeded instead of consuming device
    time.  ``None`` falls back to the service policy's default."""

    system: SNPSystem | CompiledAny
    steps: int
    policy: str = "first"       # "first" | "random"
    seed: int = 0
    max_branches: int = 64
    deadline_ms: Optional[float] = None

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.policy not in ("first", "random"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.deadline_ms is not None and self.deadline_ms < 0:
            raise ValueError("deadline_ms must be >= 0")


@dataclass(frozen=True)
class TraceResult:
    """One served trajectory, unpadded to the request's ``steps``.

    ``branch_overflow[t]`` flags that step t had more than the request's
    ``max_branches`` successors (only the first T were candidates) — the
    engine's truncation flag surfaced per trace, never silent."""

    configs: np.ndarray     # (steps, m) int32
    emissions: np.ndarray   # (steps,) int32 — the output spike train
    alive: np.ndarray       # (steps,) bool
    branch_overflow: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), bool))  # (steps,) bool

    @property
    def truncated(self) -> bool:
        """True when any step's branching was truncated to max_branches."""
        return bool(np.any(self.branch_overflow))


_STAT_KEYS = ("device_calls", "traces_served", "retries", "bisections",
              "degraded", "deadline_exceeded", "rejected", "failed_calls",
              "failed_requests", "branch_overflow_traces")


class SNPTraceService:
    """Submit/drain batching front end over :func:`run_traces`.

    ``batch_size`` is the fixed device batch: every flush runs exactly this
    many traces (padded), so a service with ``batch_size=256`` serves a
    256-request burst in **one** jitted call.  ``step_bucket`` quantizes
    requested step counts upward so distinct ``steps`` values don't each
    compile a fresh scan.

    ``runner`` overrides the device call (default
    :func:`~repro.core.engine.run_traces`); pass
    :func:`repro.serve.serve_step.make_trace_runner`'s mesh-backed runner
    to shard every flush over devices.  ``async_mode`` switches
    :meth:`submit` to return futures drained by a background flush thread
    (see the module docstring and DESIGN.md §4).

    ``policy`` (:class:`~repro.runtime.faults.FaultPolicy`) turns on the
    failure-domain machinery — deadlines, retry/backoff, degrade, bisect,
    admission control (DESIGN.md §4.4); ``None`` keeps the historical
    fail-the-whole-flush behavior.  ``fault_injector`` wraps the runner
    and compile path with a deterministic fault schedule.
    """

    def __init__(self, *, batch_size: int = 256, step_bucket: int = 16,
                 backend: BackendLike = "ref",
                 max_steps: Optional[int] = None,
                 runner: Optional[Callable] = None,
                 compile_cache_cap: int = 64,
                 async_mode: bool = False,
                 max_delay_ms: float = 10.0,
                 policy: Optional[FaultPolicy] = None,
                 fault_injector: Optional[FaultInjector] = None) -> None:
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if step_bucket < 1:
            raise ValueError("step_bucket must be >= 1")
        if compile_cache_cap < 1:
            raise ValueError("compile_cache_cap must be >= 1")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        self.batch_size = batch_size
        self.step_bucket = step_bucket
        self.max_steps = max_steps
        self.backend = get_backend(backend)
        self.policy = policy
        self.fault_injector = fault_injector
        runner = run_traces if runner is None else runner
        if fault_injector is not None:
            runner = fault_injector.runner(runner)
        self.runner = runner
        self.async_mode = async_mode
        self.max_delay_ms = max_delay_ms
        self._stats: Dict[str, int] = {k: 0 for k in _STAT_KEYS}
        #: sync-mode only, policy set: {ticket: exception} of the requests
        #: the last drain() definitively failed (replaced per drain)
        self.last_failures: Dict[int, BaseException] = {}
        self._tickets = itertools.count()
        self._pending: Dict[int, TraceRequest] = {}
        self._comp_of: Dict[int, CompiledAny] = {}   # ticket -> compiled
        # compile memoization, keyed by SNPSystem (structural equality);
        # bounded so a long-lived service can't grow without limit.  The
        # service backend is fixed at construction, so one cache per
        # service is one cache per encoding.
        self._compile_cache: Dict[SNPSystem, CompiledAny] = {}
        self._compile_cache_cap = compile_cache_cap
        # degraded-backend lowering memoization ({backend name: comp id: comp})
        self._degraded_cache: Dict[Tuple[str, int], CompiledAny] = {}
        # async state (all mutated under the one condition's lock)
        self._cv = threading.Condition()
        self._futures: Dict[int, Future] = {}
        self._submit_t: Dict[int, float] = {}
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if async_mode:
            self._thread = threading.Thread(
                target=self._drain_loop, name="snp-service-drain", daemon=True)
            self._thread.start()

    # -- observability -----------------------------------------------------

    def _count(self, key: str, n: int = 1) -> None:
        with self._cv:
            self._stats[key] += n

    def stats(self) -> Dict[str, int]:
        """Snapshot of the service counters: ``device_calls``,
        ``traces_served``, and the failure-domain counters (``retries``,
        ``bisections``, ``degraded``, ``deadline_exceeded``, ``rejected``,
        ``failed_calls``, ``failed_requests``,
        ``branch_overflow_traces``)."""
        with self._cv:
            return dict(self._stats)

    @property
    def num_device_calls(self) -> int:
        with self._cv:
            return self._stats["device_calls"]

    @property
    def num_traces_served(self) -> int:
        with self._cv:
            return self._stats["traces_served"]

    # -- submission --------------------------------------------------------

    def _compile(self, request: TraceRequest) -> CompiledAny:
        if is_compiled(request.system):
            return request.system
        # SNPSystem is a frozen dataclass: equal systems (even distinct
        # objects) share one compilation and one batch group.  The
        # backend owns the lowering (dense vs. sparse encoding).  The
        # compile itself runs *outside* the lock — it may be arbitrarily
        # expensive (StepBackend.compile contract) and must not stall the
        # drain thread past other groups' max_delay_ms deadlines.  Two
        # racing submitters may both compile; first insert wins and both
        # use it (compiles of equal systems are semantically identical),
        # keeping one batch group per system.
        with self._cv:
            comp = self._compile_cache.get(request.system)
        if comp is None:
            if self.fault_injector is not None:
                self.fault_injector.on_compile(request.system)
            comp = self.backend.compile(request.system)
            with self._cv:
                if request.system not in self._compile_cache:
                    while len(self._compile_cache) >= self._compile_cache_cap:
                        self._compile_cache.pop(
                            next(iter(self._compile_cache)))
                    self._compile_cache[request.system] = comp
                comp = self._compile_cache[request.system]
        return comp

    def submit(self, request: TraceRequest):
        """Queue a request.

        Sync mode: returns an ``int`` ticket to look up in :meth:`drain`.
        Async mode: returns a :class:`~concurrent.futures.Future` resolving
        to the request's :class:`TraceResult` (or the flush's exception).
        Under a policy with ``max_pending``, raises
        :class:`~repro.runtime.faults.AdmissionRejected` when the queue
        is full — backpressure at the door, not an unbounded queue.
        """
        if self.max_steps is not None and request.steps > self.max_steps:
            raise ValueError(
                f"steps {request.steps} exceeds service max_steps "
                f"{self.max_steps}")
        pol = self.policy
        if pol is not None and pol.max_pending is not None:
            with self._cv:
                if len(self._pending) >= pol.max_pending:
                    self._stats["rejected"] += 1
                    raise AdmissionRejected(
                        f"{len(self._pending)} requests pending >= "
                        f"max_pending={pol.max_pending}")
        comp = self._compile(request)   # outside the lock: may be expensive
        with self._cv:
            if self._closed:
                raise RuntimeError("service is closed")
            if pol is not None and pol.max_pending is not None \
                    and len(self._pending) >= pol.max_pending:
                self._stats["rejected"] += 1
                raise AdmissionRejected(
                    f"{len(self._pending)} requests pending >= "
                    f"max_pending={pol.max_pending}")
            ticket = next(self._tickets)
            self._pending[ticket] = request
            self._comp_of[ticket] = comp
            self._submit_t[ticket] = time.monotonic()
            if not self.async_mode:
                return ticket
            fut: Future = Future()
            self._futures[ticket] = fut
            self._cv.notify_all()
            return fut

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._pending)

    # -- grouping ----------------------------------------------------------

    def _group_key(self, ticket: int) -> Tuple:
        r = self._pending[ticket]
        return (id(self._comp_of[ticket]), r.policy, r.max_branches)

    def _groups(self) -> Dict[Tuple, List[int]]:
        by_group: Dict[Tuple, List[int]] = {}
        for ticket in sorted(self._pending):
            by_group.setdefault(self._group_key(ticket), []).append(ticket)
        return by_group

    def _take(self, tickets: List[int]) -> List[TraceRequest]:
        """Remove ``tickets`` from the pending maps (lock held)."""
        reqs = [self._pending.pop(t) for t in tickets]
        for t in tickets:
            self._comp_of.pop(t)
            self._submit_t.pop(t, None)
        return reqs

    # -- synchronous draining ----------------------------------------------

    def drain(self) -> Dict[int, TraceResult]:
        """Serve every pending request; returns ``{ticket: TraceResult}``.

        One jitted :func:`run_traces` call per (group, full-batch chunk).
        Sync mode only — in async mode the background thread drains and
        results arrive through the submit futures.

        Without a policy the drain is all-or-nothing: on any failure the
        whole drain stays pending and the exception raises, so a retry
        drain() re-serves everything.  With a :class:`FaultPolicy` the
        recovery machinery (deadline / retry / degrade / bisect) runs
        per chunk; requests it definitively fails are *popped* and their
        exceptions recorded in :attr:`last_failures` (and the
        ``failed_requests`` counter) while every other ticket's result
        returns — a poison request can no longer wedge the queue.
        """
        if self.async_mode:
            raise RuntimeError(
                "drain() is sync-mode only; async results arrive via the "
                "futures returned by submit()")
        results: Dict[int, TraceResult] = {}
        with self._cv:
            batches = []
            for (_, policy, max_branches), tickets in self._groups().items():
                comp = self._comp_of[tickets[0]]
                for lo in range(0, len(tickets), self.batch_size):
                    chunk = tickets[lo:lo + self.batch_size]
                    batches.append((comp, policy, max_branches, chunk,
                                    [self._pending[t] for t in chunk]))
            born = dict(self._submit_t)
        if self.policy is None:
            for comp, policy, max_branches, chunk, reqs in batches:
                results.update(self._run_batch(comp, policy, max_branches,
                                               chunk, reqs))
            # all-or-nothing: requests leave the pending maps only after
            # every batch served.  If any runner call raises, the whole
            # drain stays pending and a retry drain() re-serves it —
            # re-running a chunk that already succeeded is free of harm
            # (traces are deterministic functions of their seeds), whereas
            # popping per chunk would lose served results when a later
            # chunk fails.
            with self._cv:
                for _, _, _, chunk, _ in batches:
                    self._take(chunk)
            return results
        failures: Dict[int, BaseException] = {}
        for comp, policy, max_branches, chunk, reqs in batches:
            res, fail = self._serve_chunk(comp, policy, max_branches,
                                          chunk, reqs, born)
            results.update(res)
            failures.update(fail)
        # under a policy every ticket was definitively resolved — served,
        # deadline-expired, or isolated-and-failed — so everything pops
        with self._cv:
            for _, _, _, chunk, _ in batches:
                self._take(chunk)
        self.last_failures = failures
        return results

    # -- the device call ---------------------------------------------------

    def _run_batch(self, comp: CompiledAny, policy: str, max_branches: int,
                   tickets: List[int], reqs: List[TraceRequest],
                   backend=None) -> Dict[int, TraceResult]:
        # submit() enforces steps <= max_steps, so no clamp is needed here
        backend = self.backend if backend is None else backend
        steps = _round_up(max(r.steps for r in reqs), self.step_bucket)
        seeds = np.zeros((self.batch_size,), np.uint32)   # dummy pad: seed 0
        seeds[:len(reqs)] = [r.seed for r in reqs]

        out = self.runner(
            comp, steps=steps, seeds=seeds, policy=policy,
            max_branches=max_branches, backend=backend)
        if len(out) == 4:
            cfgs, emis, alive, ovf = out
        else:   # third-party runner predating the branch_overflow field
            cfgs, emis, alive = out
            ovf = np.zeros(np.asarray(alive).shape, bool)
        self._count("device_calls")
        self._count("traces_served", len(reqs))

        cfgs, emis, alive, ovf = (np.asarray(cfgs), np.asarray(emis),
                                  np.asarray(alive), np.asarray(ovf))
        results = {
            t: TraceResult(configs=cfgs[i, :r.steps],
                           emissions=emis[i, :r.steps],
                           alive=alive[i, :r.steps],
                           branch_overflow=ovf[i, :r.steps])
            for i, (t, r) in enumerate(zip(tickets, reqs))
        }
        truncated = sum(1 for r in results.values() if r.truncated)
        if truncated:
            self._count("branch_overflow_traces", truncated)
        return results

    # -- failure-domain recovery (policy set) ------------------------------

    def _degraded_comps(self, comp: CompiledAny):
        """Yield ``(backend, lowered comp)`` down the encoding-compatible
        degrade chain for this service's backend (DESIGN.md §4.4).  The
        chunk's compiled encoding is reused as-is — degradation swaps the
        *step implementation*, never the encoding — so re-lowering is
        cheap and memoized."""
        if isinstance(comp, CompiledSparseSNP):
            enc = "hybrid" if comp.is_hybrid else "ell"
        else:
            enc = "dense"
        for cand, plan in failover.degrade_candidates(
                self.backend, SystemPlan(encoding=enc)):
            key = (cand.name, id(comp))
            try:
                with self._cv:
                    lowered = self._degraded_cache.get(key)
                if lowered is None:
                    lowered = lower_with_backend(cand, comp, plan)
                    with self._cv:
                        self._degraded_cache[key] = lowered
            except Exception:
                continue    # candidate can't lower this encoding: skip
            yield cand, lowered

    def _serve_chunk(self, comp: CompiledAny, policy: str, max_branches: int,
                     tickets: List[int], reqs: List[TraceRequest],
                     born: Dict[int, float], depth: int = 0,
                     ) -> Tuple[Dict[int, TraceResult],
                                Dict[int, BaseException]]:
        """Serve one chunk under the failure-domain state machine
        (DESIGN.md §4.4): deadline-filter -> run -> retry/backoff ->
        degrade -> bisect -> fail the irreducible request with the *last*
        underlying exception.  Returns ``(results, failures)``; every
        input ticket lands in exactly one of the two."""
        pol = self.policy
        results: Dict[int, TraceResult] = {}
        failures: Dict[int, BaseException] = {}

        # fail fast on expired deadlines: no device time for dead requests
        now = time.monotonic()
        live_t, live_r = [], []
        for t, r in zip(tickets, reqs):
            limit = r.deadline_ms if r.deadline_ms is not None \
                else pol.deadline_ms
            t0 = born.get(t)
            if limit is not None and t0 is not None \
                    and (now - t0) * 1e3 > limit:
                failures[t] = DeadlineExceeded(
                    f"request waited {(now - t0) * 1e3:.1f} ms "
                    f"> deadline {limit:g} ms")
                self._count("deadline_exceeded")
                continue
            live_t.append(t)
            live_r.append(r)
        if not live_t:
            return results, failures

        # retry with exponential backoff + deterministic jitter.  Bisect
        # halves (depth > 0) run once: the parent already burned the
        # retry budget, and a persistent fault never clears by retry.
        retries = pol.max_retries if depth == 0 else 0
        last: Optional[BaseException] = None
        for attempt in range(retries + 1):
            if attempt:
                self._count("retries")
                time.sleep(pol.backoff_s(attempt - 1, token=live_t[0]))
            try:
                results.update(self._run_batch(
                    comp, policy, max_branches, live_t, live_r))
                return results, failures
            except Exception as e:
                last = e
                self._count("failed_calls")
                if isinstance(e, InjectedFault) and type(e) is not \
                        InjectedFault and attempt == 0:
                    # PoisonError subclass: persistent by contract —
                    # retries never clear it, go isolate instead
                    break

        # whole-chunk backend degradation (encoding-compatible chain).
        # Injected faults model node loss, not a broken backend — a
        # degraded backend would re-run the same schedule and fail again.
        if pol.degrade and depth == 0 \
                and not isinstance(last, InjectedFault):
            for cand, lowered in self._degraded_comps(comp):
                try:
                    results.update(self._run_batch(
                        comp=lowered, policy=policy,
                        max_branches=max_branches, tickets=live_t,
                        reqs=live_r, backend=cand))
                except Exception as e:
                    last = e
                    self._count("failed_calls")
                    continue
                self._count("degraded")
                failover.record_degradation(
                    self.backend.name, cand.name, "serve", last)
                return results, failures

        # bisect: split the chunk to isolate the poison request — the good
        # half re-runs for free (seed-determinism), the bad half narrows
        if pol.bisect and len(live_t) > 1:
            self._count("bisections")
            mid = len(live_t) // 2
            for lo, hi in ((0, mid), (mid, len(live_t))):
                res, fail = self._serve_chunk(
                    comp, policy, max_branches, live_t[lo:hi],
                    live_r[lo:hi], born, depth + 1)
                results.update(res)
                failures.update(fail)
            return results, failures

        # irreducible: the request itself is the failure domain
        for t in live_t:
            failures[t] = last
            self._count("failed_requests")
        return results, failures

    # -- asynchronous draining ---------------------------------------------
    #
    # State machine (DESIGN.md §4): a group is FILLING until either
    # (a) it holds >= batch_size requests -> its full chunks flush now, or
    # (b) it's oldest request is older than max_delay_ms -> the whole group
    #     (one padded partial chunk) flushes now, or
    # (c) the service closes -> everything flushes.
    # The background thread sleeps until the earliest deadline or a submit
    # notification, whichever comes first.  _take_ready and _next_deadline
    # compare time through the *same* `submit_t + delay` expression, so a
    # group is overdue iff its remaining wait is exactly 0.0 — the thread
    # can never be told "nothing to flush" and "wait 0 seconds" at once
    # (the max_delay_ms=0 busy-spin this once risked).

    def _take_ready(self, now: float, flush_all: bool) -> List[Tuple]:
        """Pop every chunk that must flush now (lock held)."""
        delay = self.max_delay_ms / 1e3
        batches: List[Tuple] = []
        for (_, policy, max_branches), tickets in self._groups().items():
            comp = self._comp_of[tickets[0]]
            take: List[int] = []
            if flush_all or now >= self._submit_t[tickets[0]] + delay:
                take = tickets
            elif len(tickets) >= self.batch_size:
                n_full = (len(tickets) // self.batch_size) * self.batch_size
                take = tickets[:n_full]
            for lo in range(0, len(take), self.batch_size):
                chunk = take[lo:lo + self.batch_size]
                futs = [self._futures.pop(t) for t in chunk]
                born = {t: self._submit_t[t] for t in chunk}
                batches.append((comp, policy, max_branches, chunk,
                                self._take(chunk), futs, born))
        return batches

    def _next_deadline(self, now: float) -> Optional[float]:
        """Seconds until the earliest group deadline (lock held)."""
        if not self._submit_t:
            return None
        oldest = min(self._submit_t.values())
        return max(0.0, oldest + self.max_delay_ms / 1e3 - now)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                now = time.monotonic()
                batches = self._take_ready(now, flush_all=self._closed)
                if not batches:
                    if self._closed:
                        return
                    timeout = self._next_deadline(now)
                    if timeout is not None and timeout <= 0:
                        # unreachable by construction (see the state-
                        # machine note above), but never wait(<=0): loop
                        # and re-take instead of spinning
                        continue
                    self._cv.wait(timeout=timeout)
                    continue
            for comp, policy, max_branches, tickets, reqs, futs, born \
                    in batches:
                # claim RUNNING state first: a caller-cancelled future must
                # be skipped, not written to (set_result on a cancelled
                # Future raises and would kill this thread); once RUNNING,
                # cancel() can no longer win the race.
                live = [fut.set_running_or_notify_cancel() for fut in futs]
                if self.policy is None:
                    try:
                        results = self._run_batch(
                            comp, policy, max_branches, tickets, reqs)
                    except BaseException as e:  # propagate into the futures
                        for fut, ok in zip(futs, live):
                            if ok:
                                fut.set_exception(e)
                        continue
                    for t, fut, ok in zip(tickets, futs, live):
                        if ok:
                            fut.set_result(results[t])
                    continue
                try:
                    results, failures = self._serve_chunk(
                        comp, policy, max_branches, tickets, reqs, born)
                except BaseException as e:  # recovery itself failed
                    results, failures = {}, {t: e for t in tickets}
                for t, fut, ok in zip(tickets, futs, live):
                    if not ok:
                        continue   # cancelled before the flush claimed it
                    if t in results:
                        fut.set_result(results[t])
                    else:
                        fut.set_exception(failures.get(t, RuntimeError(
                            f"request {t} left unserved by recovery")))

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Flush everything pending and stop the drain thread (async mode);
        idempotent, and a no-op beyond marking closed in sync mode."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "SNPTraceService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
