"""Batched SNP trace serving: heterogeneous requests -> padded device batches.

The engine's :func:`~repro.core.engine.run_traces` is the device-side hot
loop (one ``lax.scan``, whole batch through one ``StepBackend.expand`` per
step); this module is the host-side front end that makes it a service.
Architecture notes — batching/bucketing rules, the group key, the async
drain state machine, and the mesh sharding layout — live in DESIGN.md §4;
the short version:

* **sync mode** (default): :meth:`~SNPTraceService.submit` returns a
  ticket; :meth:`~SNPTraceService.drain` groups compatible requests, pads
  every group to a fixed batch size and step bucket, runs one jitted call
  per padded batch, and returns ``{ticket: TraceResult}``.
* **async mode** (``async_mode=True``): :meth:`submit` returns a
  :class:`concurrent.futures.Future`; a background flush thread fires as
  soon as a group fills a whole batch or the group's oldest request has
  waited ``max_delay_ms``.  Errors raised by a flush propagate into the
  affected futures; :meth:`close` flushes everything still pending and
  joins the thread.

Per-trace PRNG keys mean padding/batching/flush-timing never changes a
trajectory: the result for a request is bit-identical to a solo
:func:`~repro.core.engine.run_trace` with the same seed, and async results
are bit-identical to a synchronous :meth:`drain` of the same requests.

The device call is pluggable via ``runner`` (a
:func:`~repro.core.engine.run_traces`-compatible callable) so the same
front end drives the single-device path or the mesh-sharded
:func:`~repro.core.distributed.run_traces_distributed`
(:func:`repro.serve.serve_step.make_trace_runner` builds either).
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.backend import BackendLike, get_backend
from repro.core.engine import run_traces
from repro.core.matrix import CompiledAny, is_compiled
from repro.core.system import SNPSystem

__all__ = ["TraceRequest", "TraceResult", "SNPTraceService"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class TraceRequest:
    """One trajectory request: which system, how long, how to branch."""

    system: SNPSystem | CompiledAny
    steps: int
    policy: str = "first"       # "first" | "random"
    seed: int = 0
    max_branches: int = 64

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.policy not in ("first", "random"):
            raise ValueError(f"unknown policy {self.policy!r}")


@dataclass(frozen=True)
class TraceResult:
    """One served trajectory, unpadded to the request's ``steps``."""

    configs: np.ndarray     # (steps, m) int32
    emissions: np.ndarray   # (steps,) int32 — the output spike train
    alive: np.ndarray       # (steps,) bool


class SNPTraceService:
    """Submit/drain batching front end over :func:`run_traces`.

    ``batch_size`` is the fixed device batch: every flush runs exactly this
    many traces (padded), so a service with ``batch_size=256`` serves a
    256-request burst in **one** jitted call.  ``step_bucket`` quantizes
    requested step counts upward so distinct ``steps`` values don't each
    compile a fresh scan.

    ``runner`` overrides the device call (default
    :func:`~repro.core.engine.run_traces`); pass
    :func:`repro.serve.serve_step.make_trace_runner`'s mesh-backed runner
    to shard every flush over devices.  ``async_mode`` switches
    :meth:`submit` to return futures drained by a background flush thread
    (see the module docstring and DESIGN.md §4).
    """

    def __init__(self, *, batch_size: int = 256, step_bucket: int = 16,
                 backend: BackendLike = "ref",
                 max_steps: Optional[int] = None,
                 runner: Optional[Callable] = None,
                 compile_cache_cap: int = 64,
                 async_mode: bool = False,
                 max_delay_ms: float = 10.0) -> None:
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if step_bucket < 1:
            raise ValueError("step_bucket must be >= 1")
        if compile_cache_cap < 1:
            raise ValueError("compile_cache_cap must be >= 1")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        self.batch_size = batch_size
        self.step_bucket = step_bucket
        self.max_steps = max_steps
        self.backend = get_backend(backend)
        self.runner = run_traces if runner is None else runner
        self.async_mode = async_mode
        self.max_delay_ms = max_delay_ms
        self.num_device_calls = 0          # observability: jitted launches
        self.num_traces_served = 0
        self._tickets = itertools.count()
        self._pending: Dict[int, TraceRequest] = {}
        self._comp_of: Dict[int, CompiledAny] = {}   # ticket -> compiled
        # compile memoization, keyed by SNPSystem (structural equality);
        # bounded so a long-lived service can't grow without limit.  The
        # service backend is fixed at construction, so one cache per
        # service is one cache per encoding.
        self._compile_cache: Dict[SNPSystem, CompiledAny] = {}
        self._compile_cache_cap = compile_cache_cap
        # async state (all mutated under the one condition's lock)
        self._cv = threading.Condition()
        self._futures: Dict[int, Future] = {}
        self._submit_t: Dict[int, float] = {}
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if async_mode:
            self._thread = threading.Thread(
                target=self._drain_loop, name="snp-service-drain", daemon=True)
            self._thread.start()

    # -- submission --------------------------------------------------------

    def _compile(self, request: TraceRequest) -> CompiledAny:
        if is_compiled(request.system):
            return request.system
        # SNPSystem is a frozen dataclass: equal systems (even distinct
        # objects) share one compilation and one batch group.  The
        # backend owns the lowering (dense vs. sparse encoding).  The
        # compile itself runs *outside* the lock — it may be arbitrarily
        # expensive (StepBackend.compile contract) and must not stall the
        # drain thread past other groups' max_delay_ms deadlines.  Two
        # racing submitters may both compile; first insert wins and both
        # use it (compiles of equal systems are semantically identical),
        # keeping one batch group per system.
        with self._cv:
            comp = self._compile_cache.get(request.system)
        if comp is None:
            comp = self.backend.compile(request.system)
            with self._cv:
                if request.system not in self._compile_cache:
                    while len(self._compile_cache) >= self._compile_cache_cap:
                        self._compile_cache.pop(
                            next(iter(self._compile_cache)))
                    self._compile_cache[request.system] = comp
                comp = self._compile_cache[request.system]
        return comp

    def submit(self, request: TraceRequest):
        """Queue a request.

        Sync mode: returns an ``int`` ticket to look up in :meth:`drain`.
        Async mode: returns a :class:`~concurrent.futures.Future` resolving
        to the request's :class:`TraceResult` (or the flush's exception).
        """
        if self.max_steps is not None and request.steps > self.max_steps:
            raise ValueError(
                f"steps {request.steps} exceeds service max_steps "
                f"{self.max_steps}")
        comp = self._compile(request)   # outside the lock: may be expensive
        with self._cv:
            if self._closed:
                raise RuntimeError("service is closed")
            ticket = next(self._tickets)
            self._pending[ticket] = request
            self._comp_of[ticket] = comp
            if not self.async_mode:
                return ticket
            fut: Future = Future()
            self._futures[ticket] = fut
            self._submit_t[ticket] = time.monotonic()
            self._cv.notify_all()
            return fut

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._pending)

    # -- grouping ----------------------------------------------------------

    def _group_key(self, ticket: int) -> Tuple:
        r = self._pending[ticket]
        return (id(self._comp_of[ticket]), r.policy, r.max_branches)

    def _groups(self) -> Dict[Tuple, List[int]]:
        by_group: Dict[Tuple, List[int]] = {}
        for ticket in sorted(self._pending):
            by_group.setdefault(self._group_key(ticket), []).append(ticket)
        return by_group

    def _take(self, tickets: List[int]) -> List[TraceRequest]:
        """Remove ``tickets`` from the pending maps (lock held)."""
        reqs = [self._pending.pop(t) for t in tickets]
        for t in tickets:
            self._comp_of.pop(t)
            self._submit_t.pop(t, None)
        return reqs

    # -- synchronous draining ----------------------------------------------

    def drain(self) -> Dict[int, TraceResult]:
        """Serve every pending request; returns ``{ticket: TraceResult}``.

        One jitted :func:`run_traces` call per (group, full-batch chunk).
        Sync mode only — in async mode the background thread drains and
        results arrive through the submit futures.
        """
        if self.async_mode:
            raise RuntimeError(
                "drain() is sync-mode only; async results arrive via the "
                "futures returned by submit()")
        results: Dict[int, TraceResult] = {}
        with self._cv:
            batches = []
            for (_, policy, max_branches), tickets in self._groups().items():
                comp = self._comp_of[tickets[0]]
                for lo in range(0, len(tickets), self.batch_size):
                    chunk = tickets[lo:lo + self.batch_size]
                    batches.append((comp, policy, max_branches, chunk,
                                    [self._pending[t] for t in chunk]))
        for comp, policy, max_branches, chunk, reqs in batches:
            results.update(self._run_batch(comp, policy, max_branches,
                                           chunk, reqs))
        # all-or-nothing: requests leave the pending maps only after every
        # batch served.  If any runner call raises, the whole drain stays
        # pending and a retry drain() re-serves it — re-running a chunk
        # that already succeeded is free of harm (traces are deterministic
        # functions of their seeds), whereas popping per chunk would lose
        # served results when a later chunk fails.
        with self._cv:
            for _, _, _, chunk, _ in batches:
                self._take(chunk)
        return results

    # -- the device call ---------------------------------------------------

    def _run_batch(self, comp: CompiledAny, policy: str, max_branches: int,
                   tickets: List[int], reqs: List[TraceRequest],
                   ) -> Dict[int, TraceResult]:
        # submit() enforces steps <= max_steps, so no clamp is needed here
        steps = _round_up(max(r.steps for r in reqs), self.step_bucket)
        seeds = np.zeros((self.batch_size,), np.uint32)   # dummy pad: seed 0
        seeds[:len(reqs)] = [r.seed for r in reqs]

        cfgs, emis, alive = self.runner(
            comp, steps=steps, seeds=seeds, policy=policy,
            max_branches=max_branches, backend=self.backend)
        with self._cv:
            self.num_device_calls += 1
            self.num_traces_served += len(reqs)

        cfgs, emis, alive = (np.asarray(cfgs), np.asarray(emis),
                             np.asarray(alive))
        return {
            t: TraceResult(configs=cfgs[i, :r.steps],
                           emissions=emis[i, :r.steps],
                           alive=alive[i, :r.steps])
            for i, (t, r) in enumerate(zip(tickets, reqs))
        }

    # -- asynchronous draining ---------------------------------------------
    #
    # State machine (DESIGN.md §4): a group is FILLING until either
    # (a) it holds >= batch_size requests -> its full chunks flush now, or
    # (b) its oldest request is older than max_delay_ms -> the whole group
    #     (one padded partial chunk) flushes now, or
    # (c) the service closes -> everything flushes.
    # The background thread sleeps until the earliest deadline or a submit
    # notification, whichever comes first.

    def _take_ready(self, now: float, flush_all: bool) -> List[Tuple]:
        """Pop every chunk that must flush now (lock held)."""
        delay = self.max_delay_ms / 1e3
        batches: List[Tuple] = []
        for (_, policy, max_branches), tickets in self._groups().items():
            comp = self._comp_of[tickets[0]]
            take: List[int] = []
            if flush_all or (
                    now - self._submit_t[tickets[0]] >= delay):
                take = tickets
            elif len(tickets) >= self.batch_size:
                n_full = (len(tickets) // self.batch_size) * self.batch_size
                take = tickets[:n_full]
            for lo in range(0, len(take), self.batch_size):
                chunk = take[lo:lo + self.batch_size]
                futs = [self._futures.pop(t) for t in chunk]
                batches.append((comp, policy, max_branches, chunk,
                                self._take(chunk), futs))
        return batches

    def _next_deadline(self, now: float) -> Optional[float]:
        """Seconds until the earliest group deadline (lock held)."""
        if not self._submit_t:
            return None
        oldest = min(self._submit_t.values())
        return max(0.0, oldest + self.max_delay_ms / 1e3 - now)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                now = time.monotonic()
                batches = self._take_ready(now, flush_all=self._closed)
                if not batches:
                    if self._closed:
                        return
                    self._cv.wait(timeout=self._next_deadline(now))
                    continue
            for comp, policy, max_branches, tickets, reqs, futs in batches:
                # claim RUNNING state first: a caller-cancelled future must
                # be skipped, not written to (set_result on a cancelled
                # Future raises and would kill this thread); once RUNNING,
                # cancel() can no longer win the race.
                live = [fut.set_running_or_notify_cancel() for fut in futs]
                try:
                    results = self._run_batch(
                        comp, policy, max_branches, tickets, reqs)
                except BaseException as e:  # propagate into the futures
                    for fut, ok in zip(futs, live):
                        if ok:
                            fut.set_exception(e)
                else:
                    for t, fut, ok in zip(tickets, futs, live):
                        if ok:
                            fut.set_result(results[t])

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Flush everything pending and stop the drain thread (async mode);
        idempotent, and a no-op beyond marking closed in sync mode."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "SNPTraceService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
