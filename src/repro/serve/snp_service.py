"""Batched SNP trace serving: heterogeneous requests -> padded device batches.

The engine's :func:`~repro.core.engine.run_traces` is the device-side hot
loop (one ``lax.scan``, whole batch through one ``StepBackend.expand`` per
step); this module is the host-side front end that makes it a service.
Callers :meth:`~SNPTraceService.submit` trace requests that differ in
system, step count, policy and seed; :meth:`~SNPTraceService.drain` groups
compatible requests, pads every group to a **fixed** batch size and step
count (so the jit cache stays small and device shapes never churn), runs
one jitted call per padded batch, and slices each caller's trajectory back
out.

Batching rules:

* requests with the same (compiled system, policy, max_branches) share a
  batch — seeds and step counts are free per request (steps are padded to
  the group's bucket and sliced on the way out);
* groups larger than ``batch_size`` are chunked into full batches;
* short groups are padded with dummy seeds whose results are discarded.

Per-trace PRNG keys mean padding/batching never changes a trajectory: the
result for a request is bit-identical to a solo
:func:`~repro.core.engine.run_trace` with the same seed.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.backend import BackendLike, get_backend
from repro.core.engine import run_traces
from repro.core.matrix import CompiledAny, is_compiled
from repro.core.system import SNPSystem

__all__ = ["TraceRequest", "TraceResult", "SNPTraceService"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class TraceRequest:
    """One trajectory request: which system, how long, how to branch."""

    system: SNPSystem | CompiledAny
    steps: int
    policy: str = "first"       # "first" | "random"
    seed: int = 0
    max_branches: int = 64

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.policy not in ("first", "random"):
            raise ValueError(f"unknown policy {self.policy!r}")


@dataclass(frozen=True)
class TraceResult:
    """One served trajectory, unpadded to the request's ``steps``."""

    configs: np.ndarray     # (steps, m) int32
    emissions: np.ndarray   # (steps,) int32 — the output spike train
    alive: np.ndarray       # (steps,) bool


class SNPTraceService:
    """Submit/drain batching front end over :func:`run_traces`.

    ``batch_size`` is the fixed device batch: every flush runs exactly this
    many traces (padded), so a service with ``batch_size=256`` serves a
    256-request burst in **one** jitted call.  ``step_bucket`` quantizes
    requested step counts upward so distinct ``steps`` values don't each
    compile a fresh scan.
    """

    def __init__(self, *, batch_size: int = 256, step_bucket: int = 16,
                 backend: BackendLike = "ref",
                 max_steps: Optional[int] = None) -> None:
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if step_bucket < 1:
            raise ValueError("step_bucket must be >= 1")
        self.batch_size = batch_size
        self.step_bucket = step_bucket
        self.max_steps = max_steps
        self.backend = get_backend(backend)
        self.num_device_calls = 0          # observability: jitted launches
        self.num_traces_served = 0
        self._tickets = itertools.count()
        self._pending: Dict[int, TraceRequest] = {}
        self._comp_of: Dict[int, CompiledAny] = {}   # ticket -> compiled
        # compile memoization, keyed by SNPSystem (structural equality);
        # bounded so a long-lived service can't grow without limit.  The
        # service backend is fixed at construction, so one cache per
        # service is one cache per encoding.
        self._compile_cache: Dict[SNPSystem, CompiledAny] = {}
        self._compile_cache_cap = 64

    # -- submission --------------------------------------------------------

    def submit(self, request: TraceRequest) -> int:
        """Queue a request; returns a ticket to look up in :meth:`drain`."""
        if self.max_steps is not None and request.steps > self.max_steps:
            raise ValueError(
                f"steps {request.steps} exceeds service max_steps "
                f"{self.max_steps}")
        comp = request.system
        if not is_compiled(comp):
            # SNPSystem is a frozen dataclass: equal systems (even distinct
            # objects) share one compilation and one batch group.  The
            # backend owns the lowering (dense vs. sparse encoding).
            if request.system not in self._compile_cache:
                while len(self._compile_cache) >= self._compile_cache_cap:
                    self._compile_cache.pop(next(iter(self._compile_cache)))
                self._compile_cache[request.system] = \
                    self.backend.compile(request.system)
            comp = self._compile_cache[request.system]
        ticket = next(self._tickets)
        self._pending[ticket] = request
        self._comp_of[ticket] = comp
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- draining ----------------------------------------------------------

    def _group_key(self, ticket: int) -> Tuple:
        r = self._pending[ticket]
        return (id(self._comp_of[ticket]), r.policy, r.max_branches)

    def drain(self) -> Dict[int, TraceResult]:
        """Serve every pending request; returns ``{ticket: TraceResult}``.

        One jitted :func:`run_traces` call per (group, full-batch chunk).
        """
        results: Dict[int, TraceResult] = {}
        by_group: Dict[Tuple, List[int]] = {}
        for ticket in sorted(self._pending):
            by_group.setdefault(self._group_key(ticket), []).append(ticket)

        for (_, policy, max_branches), tickets in by_group.items():
            comp = self._comp_of[tickets[0]]
            for lo in range(0, len(tickets), self.batch_size):
                chunk = tickets[lo:lo + self.batch_size]
                results.update(self._flush(comp, policy, max_branches, chunk))

        self._pending.clear()
        self._comp_of.clear()
        return results

    def _flush(self, comp: CompiledAny, policy: str, max_branches: int,
               tickets: List[int]) -> Dict[int, TraceResult]:
        reqs = [self._pending[t] for t in tickets]
        # submit() enforces steps <= max_steps, so no clamp is needed here
        steps = _round_up(max(r.steps for r in reqs), self.step_bucket)
        seeds = np.zeros((self.batch_size,), np.uint32)   # dummy pad: seed 0
        seeds[:len(reqs)] = [r.seed for r in reqs]

        cfgs, emis, alive = run_traces(
            comp, steps=steps, seeds=seeds, policy=policy,
            max_branches=max_branches, backend=self.backend)
        self.num_device_calls += 1
        self.num_traces_served += len(reqs)

        cfgs, emis, alive = (np.asarray(cfgs), np.asarray(emis),
                             np.asarray(alive))
        return {
            t: TraceResult(configs=cfgs[i, :r.steps],
                           emissions=emis[i, :r.steps],
                           alive=alive[i, :r.steps])
            for i, (t, r) in enumerate(zip(tickets, reqs))
        }
