"""Serving substrate: batched step factories (LM prefill/decode + SNP traces).

``prefill_step`` consumes a (B, S) request batch, returns last-position
logits + a filled KV/state cache.  ``decode_step`` advances every sequence
one token (greedy or temperature sampling).  Both are pure functions ready
for ``jax.jit`` with shardings from the plan:

* KV caches are sequence-sharded over the ``model`` axis
  (``plan.cache_specs``) — decode attention then computes *partial* softmax
  statistics per shard which XLA's SPMD partitioner combines with one small
  all-reduce (flash-decode); the 500k-token cache never gathers.
* MoE decode uses exact capacity (no drops), matching teacher forcing.

``make_trace_runner`` is the SNP analog: it builds the device call that
:class:`repro.serve.snp_service.SNPTraceService` runs per flush — the
single-device :func:`~repro.core.engine.run_traces`, or the mesh-sharded
:func:`~repro.core.distributed.run_traces_distributed` when a mesh is
given (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import forward, init_cache

__all__ = ["make_prefill_step", "make_decode_step", "sample_token",
           "make_trace_runner"]


def make_trace_runner(*, mesh=None) -> Callable:
    """A :func:`~repro.core.engine.run_traces`-compatible callable for
    :class:`~repro.serve.snp_service.SNPTraceService`.

    ``mesh=None`` returns the single-device path unchanged; with a mesh
    every flush shards its batch axis over the (flattened) mesh via
    :func:`~repro.core.distributed.run_traces_distributed` — bit-identical
    results either way, so a service can be re-pointed at a mesh without
    changing anything its callers observe.
    """
    # Local imports: repro.serve must stay importable without pulling the
    # SNP core (and its jax tracing) into LM-only entry points at load.
    if mesh is None:
        from repro.core.engine import run_traces
        return run_traces
    from repro.core.distributed import run_traces_distributed
    return functools.partial(run_traces_distributed, mesh=mesh)


def sample_token(logits: jnp.ndarray, key, temperature: float = 0.0):
    """logits (..., V) -> token ids (...,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def make_prefill_step(cfg: ArchConfig, *, max_len: int,
                      attn_impl: str = "xla",
                      constrain: Callable = lambda t, k: t):
    def prefill_step(params, batch: Dict):
        B = batch["tokens"].shape[0]
        cache = init_cache(cfg, B, max_len=max_len)
        logits, cache, _ = forward(
            params, cfg, batch, cache=cache, mode="prefill",
            attn_impl=attn_impl, constrain=constrain, logits_slice="last")
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, temperature: float = 0.0,
                     constrain: Callable = lambda t, k: t,
                     activation_stationary: bool = True):
    if activation_stationary:
        base = constrain

        def constrain(t, kind, _base=base):  # noqa: F811
            return _base(t, "hidden_decode" if kind == "hidden" else kind)

    def decode_step(params, cache, tokens, positions, key):
        """tokens (B,1) (or (B,C,1)); returns (next_tokens, logits, cache)."""
        batch = {"tokens": tokens, "positions": positions}
        logits, cache, _ = forward(
            params, cfg, batch, cache=cache, mode="decode",
            constrain=constrain)
        last = logits[:, :, -1, :] if cfg.codebooks else logits[:, -1, :]
        nxt = sample_token(last, key, temperature)
        if cfg.codebooks:
            nxt = nxt[..., None]          # (B, C, 1)
        else:
            nxt = nxt[..., None]          # (B, 1)
        return nxt, logits, cache

    return decode_step
