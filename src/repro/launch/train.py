"""End-to-end training driver.

Runs real steps on whatever devices exist (CPU smoke -> full pod), with the
full substrate engaged: sharded params/optimizer, microbatched grad
accumulation, remat, WSD/cosine schedule, async checkpointing, resume,
failure-injection drills, gradient compression.

Examples:
    # CPU-runnable reduced config, a few hundred steps
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

    # resume after interruption (picks up step + data position)
    PYTHONPATH=src python -m repro.launch.train ... --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.launch.mesh import make_production_mesh
from repro.models import init_params, param_count
from repro.runtime import FailureInjector, Supervisor, SupervisorConfig
from repro.sharding import make_plan
from repro.train import AdamWConfig, init_train_state, make_train_step


def build_mesh_for_available() -> Mesh:
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh()
    # degenerate CPU/debug meshes
    model = 1
    for cand in (8, 4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (drill)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if cfg.schedule == "wsd":
        sched = "wsd"
    else:
        sched = "cosine"
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps, schedule=sched)

    mesh = build_mesh_for_available()
    plan = make_plan(mesh)
    data_cfg = DataConfig(seed=args.seed)

    print(f"[train] arch={cfg.name} devices={mesh.devices.size} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    with mesh:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        print(f"[train] params: {param_count(params):,}")
        step_fn_raw = make_train_step(
            cfg, opt_cfg, microbatches=args.microbatches, remat=args.remat,
            constrain=plan.constrain, compression=args.compression)

        state0 = init_train_state(params, opt_cfg,
                                  compression=args.compression)
        # host snapshot: the live state is donated into the step, so any
        # restart must rebuild from host (or checkpoint) copies
        state0 = jax.tree.map(np.asarray, state0)
        state_sharding = jax.tree.map(
            plan.named, plan.param_specs(cfg, state0))
        jit_step = jax.jit(step_fn_raw, donate_argnums=(0,))

        def data_for(step: int):
            b = make_batch(cfg, data_cfg, step=step, shard=0,
                           batch=args.batch, seq_len=args.seq)
            return {k: jnp.asarray(v) for k, v in b.items()}

        t_start = time.time()
        losses = []

        if args.ckpt_dir:
            def make_step(restore_step: Optional[int]):
                state = jax.device_put(state0, state_sharding)
                if restore_step is not None:
                    template = jax.tree.map(
                        lambda l: np.zeros(l.shape, l.dtype), state0)
                    host, s, _ = restore_checkpoint(
                        args.ckpt_dir, template, step=restore_step)
                    state = jax.device_put(host, state_sharding)
                    print(f"[train] restored step {s}")
                    return state, wrapped_step, s
                start = latest_step(args.ckpt_dir)
                if start is not None:
                    return make_step(start)
                return state, wrapped_step, 0

            def wrapped_step(state, batch):
                state, metrics = jit_step(state, batch)
                losses.append(float(metrics["loss"]))
                if len(losses) % args.log_every == 0:
                    print(f"[train] step {len(losses):5d} "
                          f"loss {losses[-1]:.4f} "
                          f"({(time.time()-t_start)/len(losses):.2f}s/step)",
                          flush=True)
                return state, metrics

            sup = Supervisor(
                SupervisorConfig(ckpt_dir=args.ckpt_dir,
                                 ckpt_every=args.ckpt_every),
                make_step, data_for,
                injector=FailureInjector(args.fail_at) if args.fail_at
                else None)
            state, report = sup.run(args.steps)
            print(f"[train] done: {report}")
        else:
            state = jax.device_put(state0, state_sharding)
            for step in range(args.steps):
                state, metrics = jit_step(state, data_for(step))
                if (step + 1) % args.log_every == 0:
                    print(f"[train] step {step+1:5d} "
                          f"loss {float(metrics['loss']):.4f} "
                          f"grad_norm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e}", flush=True)
            print(f"[train] done in {time.time()-t_start:.1f}s, "
                  f"final loss {float(metrics['loss']):.4f}")
    return state


if __name__ == "__main__":
    main()
