import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: ``jax.jit(step, in_shardings, out_shardings,
donate).lower(*avals).compile()`` on the 16×16 single-pod mesh AND the
2×16×16 multi-pod mesh; record ``memory_analysis()`` (proves it fits),
``cost_analysis()`` + HLO-parsed collective bytes (feeds §Roofline).

This is the ONLY entry point allowed to fake 512 devices — the env var
above must run before any other import (jax locks device count on first
init).  Results stream to JSON per cell so partial runs are never lost.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out experiments/dryrun
    ... --arch smollm-360m --shape train_4k --mesh single   # one cell
    ... --snp                                                # SNP engine cells
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_train_state,
                                decode_input_specs, input_specs)
from repro.roofline.analysis import analyze_compiled
from repro.serve import make_decode_step, make_prefill_step
from repro.sharding import make_plan
from repro.train import AdamWConfig, make_train_step

# Per-arch training knobs chosen so activations fit 16 GB/chip under full
# remat (validated by memory_analysis; revised during §Perf iteration).
TRAIN_KNOBS: Dict[str, Dict[str, Any]] = {
    "qwen2-vl-7b":          dict(microbatches=4),
    "qwen2-moe-a2.7b":      dict(microbatches=4),
    "grok-1-314b":          dict(microbatches=16),
    "command-r-35b":        dict(microbatches=8),
    "minicpm3-4b":          dict(microbatches=4),
    "smollm-360m":          dict(microbatches=1),
    "minicpm-2b":           dict(microbatches=2),
    "jamba-1.5-large-398b": dict(microbatches=8),
    "rwkv6-7b":             dict(microbatches=4),
    "musicgen-medium":      dict(microbatches=2),
}


def _model_flops(cfg: ArchConfig, spec: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode counts one
    token per sequence, no backward (2·N·D)."""
    n_active = cfg.active_param_count()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    tokens = spec.global_batch  # decode: one new token each
    return 2.0 * n_active * tokens


def run_cell(cfg: ArchConfig, shape_name: str, multi_pod: bool,
             seq_shard: bool = False,
             microbatches: Optional[int] = None,
             remat: str = "full",
             attn_impl: str = "xla",
             expert_pad: int = 0) -> Dict:
    import dataclasses as _dc
    if expert_pad:
        cfg = _dc.replace(cfg, expert_pad_multiple=expert_pad)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    plan = make_plan(mesh, seq_shard_activations=seq_shard)
    t0 = time.time()

    with mesh:
        if spec.kind == "train":
            knobs = dict(TRAIN_KNOBS.get(cfg.name, {}))
            if microbatches is not None:
                knobs["microbatches"] = microbatches
            opt_cfg = AdamWConfig()
            step = make_train_step(cfg, opt_cfg, remat=remat,
                                   attn_impl=attn_impl,
                                   constrain=plan.constrain, **knobs)
            state = abstract_train_state(cfg, opt_cfg)
            batch = input_specs(cfg, spec)
            state_specs = jax.tree.map(
                lambda s: s, plan.param_specs(cfg, state))
            in_sh = (jax.tree.map(plan.named, state_specs),
                     jax.tree.map(plan.named, plan.batch_specs(cfg, batch)))
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif spec.kind == "prefill":
            pstep = make_prefill_step(cfg, max_len=spec.seq_len,
                                      attn_impl=attn_impl,
                                      constrain=plan.constrain)
            params = abstract_train_state(cfg, AdamWConfig()).params
            batch = input_specs(cfg, spec, with_labels=False)
            in_sh = (jax.tree.map(plan.named, plan.param_specs(cfg, params)),
                     jax.tree.map(plan.named, plan.batch_specs(cfg, batch)))
            jitted = jax.jit(pstep, in_shardings=in_sh)
            lowered = jitted.lower(params, batch)
        else:  # decode
            dstep = make_decode_step(cfg, constrain=plan.constrain)
            params = abstract_train_state(cfg, AdamWConfig()).params
            cache = abstract_cache(cfg, spec.global_batch, spec.seq_len)
            dbatch = decode_input_specs(cfg, spec)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            bspecs = jax.tree.map(plan.named,
                                  plan.batch_specs(cfg, dbatch))
            in_sh = (
                jax.tree.map(plan.named, plan.param_specs(cfg, params)),
                jax.tree.map(plan.named, plan.cache_specs(cfg, cache)),
                bspecs["tokens"],
                bspecs["positions"],
                plan.named(jax.sharding.PartitionSpec()),
            )
            jitted = jax.jit(dstep, in_shardings=in_sh,
                             donate_argnums=(1,))
            lowered = jitted.lower(params, cache, dbatch["tokens"],
                                   dbatch["positions"], key)
        compiled = lowered.compile()

    record = analyze_compiled(
        lowered, compiled, chips=chips,
        model_flops=_model_flops(cfg, spec),
        default_group=chips)
    record.update(
        arch=cfg.name, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        seq_shard=seq_shard, remat=remat, attn_impl=attn_impl,
        expert_pad=expert_pad,
        microbatches=(microbatches
                      or TRAIN_KNOBS.get(cfg.name, {}).get("microbatches")),
        compile_seconds=round(time.time() - t0, 1),
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
    )
    return record


def run_snp_cell(multi_pod: bool, *, neurons: int = 2048, rules: int = 4096,
                 frontier_per_dev: int = 32, max_branches: int = 64) -> Dict:
    """Dry-run of the distributed SNP exploration step on the production
    mesh (the paper's workload at 'very large system' scale)."""
    import functools
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.backend import get_backend
    from repro.core.distributed import _device_step, shard_map
    from repro.core.generators import random_system
    from repro.core.matrix import compile_system

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.devices.size
    flat = Mesh(mesh.devices.reshape(-1), ("x",))
    system = random_system(neurons, max(1, rules // neurons), 8 / neurons,
                           seed=0)
    comp = compile_system(system)
    m, n = comp.num_neurons, comp.num_rules
    F, T = frontier_per_dev, max_branches
    C = max(16, (F * T) // ndev)

    step = jax.jit(
        shard_map(
            functools.partial(_device_step, axis="x", ndev=ndev,
                              max_branches=T, send_cap=C,
                              backend=get_backend("ref")),
            mesh=flat,
            in_specs=(P(), P("x"), P("x"), P("x"), P("x"), P("x"), P("x"),
                      P("x")),
            out_specs=(P("x"), P("x"), P("x"), P("x"), P("x"), P("x"),
                       P("x"), P()),
        ),
        donate_argnums=(1, 2, 3, 4, 5, 6, 7),
    )
    V = 4096
    sds = jax.ShapeDtypeStruct
    args = (
        jax.eval_shape(lambda: comp),
        sds((ndev * F, m), jnp.int32), sds((ndev * F,), jnp.bool_),
        sds((ndev * V,), jnp.uint32), sds((ndev * V,), jnp.uint32),
        sds((ndev * V, m), jnp.int32), sds((ndev,), jnp.int32),
        sds((ndev, 3), jnp.bool_),
    )
    with flat:
        lowered = step.lower(*args)
        compiled = lowered.compile()
    record = analyze_compiled(lowered, compiled, chips=ndev,
                              default_group=ndev)
    record.update(arch=f"snp-{neurons}n-{n}r", shape="explore_step",
                  mesh="2x16x16" if multi_pod else "16x16", chips=ndev,
                  compile_seconds=round(time.time() - t0, 1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--snp", action="store_true",
                    help="also dry-run the SNP exploration step")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn-impl", default="xla",
                    choices=["xla", "chunked", "pallas"])
    ap.add_argument("--expert-pad", type=int, default=0)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results, failures = [], []

    def emit(rec):
        results.append(rec)
        path = os.path.join(
            args.out, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
        print(f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} "
              f"{rec['mesh']:8s} compute={rec.get('compute_s', 0):.4f}s "
              f"memory={rec.get('memory_s', 0):.4f}s "
              f"collective={rec.get('collective_s', 0):.4f}s "
              f"bound={rec.get('bound')} "
              f"({rec['compile_seconds']}s compile)", flush=True)

    for name in archs:
        cfg = get_config(name)
        for shape in shapes:
            if shape == "long_500k" and not cfg.supports_long_context:
                print(f"[dryrun] {name:24s} long_500k    SKIP "
                      "(pure full attention, DESIGN.md §5)", flush=True)
                continue
            for multi in meshes:
                try:
                    emit(run_cell(cfg, shape, multi,
                                  seq_shard=args.seq_shard,
                                  microbatches=args.microbatches,
                                  remat=args.remat,
                                  attn_impl=args.attn_impl,
                                  expert_pad=args.expert_pad))
                except Exception as e:
                    failures.append((name, shape, multi, repr(e)))
                    print(f"[dryrun] FAIL {name} {shape} "
                          f"{'multi' if multi else 'single'}: {e}",
                          flush=True)
                    traceback.print_exc()

    if args.snp:
        for multi in meshes:
            try:
                emit(run_snp_cell(multi))
            except Exception as e:
                failures.append(("snp", "explore", multi, repr(e)))
                traceback.print_exc()

    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1,
                  default=float)
    print(f"\n[dryrun] {len(results)} cells OK, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
