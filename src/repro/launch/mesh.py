"""Production mesh construction.

A function (not module-level constant) so importing never touches jax
device state.  Shapes per the brief: single pod = (16, 16) (data, model)
= 256 chips; multi-pod = (2, 16, 16) (pod, data, model) = 512 chips.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
