"""Batched serving drivers: the LM path (prefill + streamed decode) and the
SNP trace path (mesh-backed async service).

CPU-runnable with --smoke; on a pod the same code paths serve the full
config with sequence-sharded KV caches (LM) or the whole mesh as one
data-parallel trace axis (SNP, DESIGN.md §4).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 64 --gen 32

    PYTHONPATH=src python -m repro.launch.serve --snp \
        --batch 64 --requests 256 --gen 32 --max-delay-ms 5
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.launch.train import build_mesh_for_available
from repro.models import init_params
from repro.serve import (SNPTraceService, TraceRequest, make_decode_step,
                         make_prefill_step, make_trace_runner)
from repro.sharding import make_plan


def serve_snp(args) -> None:
    """Stand up the mesh-backed async SNP trace service and serve a burst.

    The mesh is the plan's full device set flattened onto one ``traces``
    axis (`plan.trace_mesh()`); every flush of the service shards its
    batch over it via :func:`repro.core.distributed.run_traces_distributed`
    — bit-identical to single-device serving, so this driver doubles as a
    correctness check on whatever devices are available.
    """
    from repro.core import paper_pi
    from repro.runtime import FaultInjector, FaultPolicy

    mesh = build_mesh_for_available()
    plan = make_plan(mesh)
    trace_mesh = plan.trace_mesh()
    runner = make_trace_runner(mesh=trace_mesh)
    system = paper_pi(covering=True)

    policy = None
    if (args.max_retries is not None or args.deadline_ms is not None
            or args.max_pending is not None or args.inject):
        policy = FaultPolicy(
            max_retries=2 if args.max_retries is None else args.max_retries,
            backoff_ms=args.backoff_ms,
            deadline_ms=args.deadline_ms,
            max_pending=args.max_pending)
    injector = None
    if args.inject:
        # "fail=2,4 poison=17 slow=3:0.05" -> a deterministic schedule
        kw = {}
        for part in args.inject.split():
            k, _, v = part.partition("=")
            if k == "fail":
                kw["fail_calls"] = [int(x) for x in v.split(",") if x]
            elif k == "poison":
                kw["poison_seeds"] = [int(x) for x in v.split(",") if x]
            elif k == "slow":
                kw["slow_calls"] = {
                    int(o): float(s) for o, s in
                    (pair.split(":") for pair in v.split(","))}
            else:
                raise SystemExit(f"unknown --inject term {part!r}")
        injector = FaultInjector(**kw)

    n, G = args.requests, args.gen
    with SNPTraceService(batch_size=args.batch, step_bucket=8,
                         backend=args.backend, runner=runner,
                         async_mode=True,
                         max_delay_ms=args.max_delay_ms,
                         policy=policy, fault_injector=injector) as svc:
        print(f"[serve-snp] mesh {trace_mesh.devices.size}-device, "
              f"batch {args.batch}, max_delay {args.max_delay_ms} ms, "
              f"backend {args.backend}"
              + (f", policy {policy}" if policy else ""))
        done = {}
        t0 = time.perf_counter()
        futs = []
        for s in range(n):
            fut = svc.submit(TraceRequest(system, steps=G, policy="random",
                                          seed=s))
            # completion timestamps via callback: waiting on futs in order
            # would attribute earlier futures' wait to later ones
            fut.add_done_callback(
                lambda f, s=s: done.setdefault(s, time.perf_counter()))
            futs.append(fut)
        failed = 0
        for f in futs:
            try:
                f.result()
            except Exception as e:
                failed += 1
                print(f"[serve-snp] request failed: {type(e).__name__}: {e}")
        dt = time.perf_counter() - t0
        calls = svc.num_device_calls
        stats = svc.stats()
    # outside the with-block: close() joined the drain thread, so every
    # done-callback has run (result() alone doesn't guarantee the last
    # future's callback fired before the waiter woke)
    lat_ms = np.asarray([done[s] - t0 for s in range(n)]) * 1e3
    print(f"[serve-snp] {n - failed}/{n} traces x {G} steps in "
          f"{dt*1e3:.1f} ms ({n / dt:.0f} traces/s, {calls} device calls)")
    print(f"[serve-snp] completion latency p50={np.percentile(lat_ms, 50):.1f} ms "
          f"p99={np.percentile(lat_ms, 99):.1f} ms")
    if policy is not None or injector is not None:
        print("[serve-snp] fault stats: " + ", ".join(
            f"{k}={v}" for k, v in stats.items() if v))
    ok = next((f for f in futs if not f.exception()), None)
    if ok is not None:
        emis = np.asarray(ok.result().emissions)
        print(f"[serve-snp] sample spike train: {emis.tolist()}")


def serve_lm(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = build_mesh_for_available()
    plan = make_plan(mesh)
    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G + 1

    with mesh:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        prefill = jax.jit(make_prefill_step(cfg, max_len=max_len,
                                            constrain=plan.constrain))
        decode = jax.jit(make_decode_step(cfg,
                                          temperature=args.temperature,
                                          constrain=plan.constrain))

        batch = make_batch(cfg, DataConfig(seed=args.seed), step=0, shard=0,
                           batch=B, seq_len=S)
        batch = {k: jnp.asarray(v) for k, v in batch.items()
                 if k not in ("labels",)}

        t0 = time.time()
        logits, cache = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
              f"({B*S/t_prefill:.0f} tok/s)")

        last = logits[:, :, -1, :] if cfg.codebooks else logits[:, -1, :]
        tok = jnp.argmax(last, -1).astype(jnp.int32)[..., None]
        key = jax.random.PRNGKey(args.seed)
        outs = []
        t0 = time.time()
        for g in range(G):
            pos = jnp.full((B, 1), S + g, jnp.int32)
            if cfg.mrope_sections:
                pos = jnp.broadcast_to(pos[None], (3, B, 1))
            key, sub = jax.random.split(key)
            tok, logits, cache = decode(params, cache, tok, pos, sub)
            outs.append(np.asarray(tok)[..., 0])
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"[serve] decode {G} steps: {dt/G*1e3:.2f} ms/step "
              f"({B*G/dt:.0f} tok/s)")
        gen = np.stack(outs, -1)
        print(f"[serve] sample generations (first 16 token ids/request):")
        for b in range(min(B, 4)):
            row = gen[b] if not cfg.codebooks else gen[b, 0]
            print(f"  req{b}: {row[:16].tolist()}")
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--snp", action="store_true",
                    help="serve SNP traces (mesh-backed async service) "
                         "instead of the LM path")
    ap.add_argument("--arch", default=None,
                    help="LM config name (required without --snp)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="request batch (default: 4 for the LM path, 256 — "
                         "the service batch_size — for --snp)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # SNP service knobs
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--backend", default="ref")
    # failure-domain knobs: any of these turns on the FaultPolicy path
    ap.add_argument("--max-retries", type=int, default=None,
                    help="retries per flush before degrade/bisect "
                         "(default 2 once any fault flag is set)")
    ap.add_argument("--backoff-ms", type=float, default=10.0,
                    help="base retry backoff (exponential, jittered)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expired requests fail "
                         "fast with DeadlineExceeded")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission control: reject submits past this "
                         "queue depth")
    ap.add_argument("--inject", default=None,
                    help="deterministic fault schedule, e.g. "
                         "'fail=2,4 poison=17 slow=3:0.05'")
    args = ap.parse_args(argv)

    if args.batch is None:
        args.batch = 256 if args.snp else 4
    if args.snp:
        return serve_snp(args)
    if args.arch is None:
        ap.error("--arch is required without --snp")
    return serve_lm(args)


if __name__ == "__main__":
    main()
