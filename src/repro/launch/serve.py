"""Batched serving driver: prefill a request batch, stream decode steps.

CPU-runnable with --smoke; on a pod the same code path serves the full
config with sequence-sharded KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.launch.train import build_mesh_for_available
from repro.models import init_params
from repro.serve import make_decode_step, make_prefill_step
from repro.sharding import make_plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = build_mesh_for_available()
    plan = make_plan(mesh)
    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G + 1

    with mesh:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        prefill = jax.jit(make_prefill_step(cfg, max_len=max_len,
                                            constrain=plan.constrain))
        decode = jax.jit(make_decode_step(cfg,
                                          temperature=args.temperature,
                                          constrain=plan.constrain))

        batch = make_batch(cfg, DataConfig(seed=args.seed), step=0, shard=0,
                           batch=B, seq_len=S)
        batch = {k: jnp.asarray(v) for k, v in batch.items()
                 if k not in ("labels",)}

        t0 = time.time()
        logits, cache = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
              f"({B*S/t_prefill:.0f} tok/s)")

        last = logits[:, :, -1, :] if cfg.codebooks else logits[:, -1, :]
        tok = jnp.argmax(last, -1).astype(jnp.int32)[..., None]
        key = jax.random.PRNGKey(args.seed)
        outs = []
        t0 = time.time()
        for g in range(G):
            pos = jnp.full((B, 1), S + g, jnp.int32)
            if cfg.mrope_sections:
                pos = jnp.broadcast_to(pos[None], (3, B, 1))
            key, sub = jax.random.split(key)
            tok, logits, cache = decode(params, cache, tok, pos, sub)
            outs.append(np.asarray(tok)[..., 0])
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"[serve] decode {G} steps: {dt/G*1e3:.2f} ms/step "
              f"({B*G/dt:.0f} tok/s)")
        gen = np.stack(outs, -1)
        print(f"[serve] sample generations (first 16 token ids/request):")
        for b in range(min(B, 4)):
            row = gen[b] if not cfg.codebooks else gen[b, 0]
            print(f"  req{b}: {row[:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
