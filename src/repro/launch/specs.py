"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every model input/state — weak-type-correct, shardable, zero allocation.

``input_specs(cfg, shape)`` produces the batch aval for a shape cell;
``abstract_state``/``abstract_cache`` produce parameter/optimizer/cache
avals via ``jax.eval_shape`` so the full 314B-scale trees exist only as
metadata.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import init_cache, init_params
from repro.train import AdamWConfig, init_train_state

__all__ = ["input_specs", "abstract_params", "abstract_train_state",
           "abstract_cache", "decode_input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, spec: ShapeSpec,
                with_labels: bool = True) -> Dict[str, Any]:
    """Training/prefill batch avals (tokens/positions/labels + frontend
    stubs)."""
    B, S = spec.global_batch, spec.seq_len
    tok_shape = (B, cfg.codebooks, S) if cfg.codebooks else (B, S)
    batch = {
        "tokens": _sds(tok_shape, jnp.int32),
        "positions": _sds((3, B, S) if cfg.mrope_sections else (B, S),
                          jnp.int32),
    }
    if with_labels:
        batch["labels"] = _sds(tok_shape, jnp.int32)
    if cfg.frontend != "none" and not cfg.codebooks:
        batch["frontend_embeds"] = _sds((B, S, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        batch["embed_mask"] = _sds((B, S), jnp.bool_)
    return batch


def decode_input_specs(cfg: ArchConfig, spec: ShapeSpec) -> Dict[str, Any]:
    """Decode-step avals: one new token against a seq_len-deep cache."""
    B = spec.global_batch
    tok_shape = (B, cfg.codebooks, 1) if cfg.codebooks else (B, 1)
    return {
        "tokens": _sds(tok_shape, jnp.int32),
        "positions": _sds((3, B, 1) if cfg.mrope_sections else (B, 1),
                          jnp.int32),
    }


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def abstract_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig,
                         compression: bool = False):
    params = abstract_params(cfg)
    return jax.eval_shape(
        lambda p: init_train_state(p, opt_cfg, compression), params)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len))
