"""Deterministic shardable resumable data pipeline."""

from .pipeline import DataConfig, data_iterator, dedup_batch, make_batch

__all__ = ["DataConfig", "make_batch", "data_iterator", "dedup_batch"]
