"""Deterministic, shardable, resumable synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` — no filesystem,
no RNG state to lose: resuming from a checkpoint's ``step`` reproduces the
exact token stream, and each data-parallel shard draws only its slice
(host-local arrays; the launcher assembles global arrays per mesh).

The generator emits document-structured token streams (Zipfian unigrams per
pseudo-document, BOS-delimited) so losses move like language data rather
than uniform noise.  An exact-dedup filter (same hash-partition machinery
as the SNP engine's visited set) is included to mirror a production
dedup stage and is reused by tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["DataConfig", "make_batch", "data_iterator", "dedup_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    doc_len_mean: int = 512
    bos_token: int = 1


def _rng_for(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def _zipf_tokens(rng, n, vocab):
    # Zipf-ish unigram draw, cheap and bounded
    u = rng.random(n)
    ranks = np.minimum((1.0 / np.maximum(u, 1e-9)) ** 0.7, vocab - 2)
    toks = ranks.astype(np.int64)
    perm_seed = rng.integers(0, 2 ** 31)
    # per-document token permutation so documents differ in content
    return (toks * 2654435761 + perm_seed) % (vocab - 2) + 2


def make_batch(
    arch: ArchConfig, data_cfg: DataConfig, *, step: int, shard: int,
    batch: int, seq_len: int,
) -> Dict[str, np.ndarray]:
    """One shard-local batch: tokens/labels/positions (+frontend stubs)."""
    rng = _rng_for(data_cfg, step, shard)
    V = arch.vocab_size
    ncb = max(1, arch.codebooks)
    total = batch * ncb * seq_len + batch
    toks = _zipf_tokens(rng, total, V)
    # BOS-delimit pseudo-documents
    doc_mask = rng.random(total) < 1.0 / max(data_cfg.doc_len_mean, 2)
    toks = np.where(doc_mask, data_cfg.bos_token, toks)
    if arch.codebooks:
        tokens = toks[:batch * ncb * seq_len].reshape(batch, ncb, seq_len)
        labels = np.roll(tokens, -1, axis=-1)
    else:
        tokens = toks[:batch * seq_len].reshape(batch, seq_len)
        labels = np.roll(tokens, -1, axis=-1)
    labels = labels.copy()
    labels[..., -1] = -1   # no target for the final position
    positions = np.broadcast_to(np.arange(seq_len), (batch, seq_len)).copy()
    out = {
        "tokens": tokens.astype(np.int32),
        "labels": labels.astype(np.int32),
        "positions": positions.astype(np.int32),
    }
    if arch.mrope_sections:
        out["positions"] = np.broadcast_to(
            out["positions"][None], (3, batch, seq_len)).copy()
    if arch.frontend != "none" and not arch.codebooks:
        out["frontend_embeds"] = rng.standard_normal(
            (batch, seq_len, arch.d_model)).astype(np.float32)
        out["embed_mask"] = (
            np.arange(seq_len)[None, :] < seq_len // 8
        ).repeat(batch, 0)
    return out


def data_iterator(
    arch: ArchConfig, data_cfg: DataConfig, *, shard: int, batch: int,
    seq_len: int, start_step: int = 0,
) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
    """Resumable: pass the checkpointed step as ``start_step`` and the
    stream continues bit-identically."""
    step = start_step
    while True:
        yield step, make_batch(arch, data_cfg, step=step, shard=shard,
                               batch=batch, seq_len=seq_len)
        step += 1


def dedup_batch(tokens: np.ndarray) -> np.ndarray:
    """Exact duplicate-sequence mask (True = keep): the data-pipeline twin
    of the SNP visited-set dedup."""
    seen = set()
    keep = np.ones(tokens.shape[0], bool)
    for i, row in enumerate(tokens.reshape(tokens.shape[0], -1)):
        h = hash(row.tobytes())
        if h in seen:
            keep[i] = False
        seen.add(h)
    return keep
