"""Synthetic SNP-system families for scaling benchmarks and stress tests.

The paper evaluates on the single 3-neuron Π; to measure how the engine
scales with system size (neurons, rules, synapse density, nondeterministic
width) we need parameterized families, all valid SNPSystems:

* ``ring``            — deterministic m-neuron ring, one a->a rule each.
* ``nd_chain``        — k neurons with two applicable rules each: Ψ = 2^k
                        branching, worst-case enumeration stress.
* ``random_system``   — Erdős–Rényi synapse graph with random rules;
                        branching statistically controlled.
* ``counter``         — b-bit ripple counter (2-neuron pacemaker + divider
                        chain): long deterministic runs with a known exact
                        trajectory (period-2^b limit cycle, ≥ 2^b distinct
                        configs).
* ``scaled_pi``       — k disjoint copies of the paper's Π fused into one
                        system: tree = product of k independent Π trees;
                        lets us grow the paper's own workload.

Large-system families (bounded synapse degree, O(m·degree) construction —
the sparse-backend benchmark tier; ``random_system``'s O(m²) edge scan is
unusable past a few thousand neurons):

* ``ring_lattice``    — each neuron feeds its next ``degree`` ring
                        neighbors: exact, uniform out-degree.
* ``torus``           — 2-D wrap-around grid, 4-neighborhood (degree 4).
* ``power_law``       — preferential attachment: bounded *mean* degree
                        with heavy-tailed in-degree, the adversarial case
                        for ELL row packing.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Optional, Sequence, Tuple, Union

from .system import Rule, SNPSystem

__all__ = ["ring", "nd_chain", "random_system", "counter", "scaled_pi",
           "ring_lattice", "torus", "power_law", "with_delays"]


def ring(m: int, produce: int = 1) -> SNPSystem:
    rules = tuple(
        Rule(neuron=i, consume=1, produce=produce, regex_base=1, covering=True)
        for i in range(m)
    )
    syn = tuple((i, (i + 1) % m) for i in range(m))
    init = tuple(1 if i == 0 else 0 for i in range(m))
    return SNPSystem(m, init, rules, syn, output_neuron=m - 1,
                     name=f"ring-{m}")


def nd_chain(k: int) -> SNPSystem:
    """Every neuron holds 1 spike and may either relay or forget: Ψ = 2^k."""
    rules = []
    for i in range(k):
        rules.append(Rule(neuron=i, consume=1, produce=1, regex_base=1,
                          covering=True))
        rules.append(Rule(neuron=i, consume=1, produce=0, regex_base=1,
                          covering=True))
    syn = tuple((i, i + 1) for i in range(k - 1))
    return SNPSystem(k, (1,) * k, tuple(rules), syn, output_neuron=k - 1,
                     name=f"nd-chain-{k}")


def random_system(
    m: int,
    rules_per_neuron: int = 2,
    synapse_prob: float = 0.25,
    max_spikes: int = 3,
    seed: int = 0,
) -> SNPSystem:
    rng = random.Random(seed)
    rules = []
    for i in range(m):
        for _ in range(rules_per_neuron):
            consume = rng.randint(1, max_spikes)
            base = rng.randint(consume, max_spikes)
            rules.append(Rule(
                neuron=i, consume=consume,
                produce=rng.choice([0, 1, 1, 2]),
                regex_base=base,
                regex_period=rng.choice([0, 0, 1]),
                covering=rng.random() < 0.5,
            ))
    syn = tuple(
        (i, j) for i in range(m) for j in range(m)
        if i != j and rng.random() < synapse_prob
    )
    init = tuple(rng.randint(0, max_spikes) for _ in range(m))
    return SNPSystem(m, init, tuple(rules), syn, output_neuron=m - 1,
                     name=f"random-{m}x{rules_per_neuron}-s{seed}")


def counter(bits: int) -> SNPSystem:
    """A deterministic b-bit ripple counter: period-doubling divider chain.

    Self-synapses are forbidden, so the clock is a 2-neuron pacemaker
    (neurons 0 and 1) bouncing a single spike and feeding divider stage 0
    every step.  Divider stage ``i`` (neuron ``2 + i``) accumulates spikes
    and fires exactly at 2 (``a^2/a^2 -> a``, exact mode), halving the rate:
    stage ``i`` fires every ``2^(i+1)`` steps, and its held spike count is
    bit ``i`` of a binary counter.  The trajectory is a limit cycle of
    period ``2^bits`` (plus a short chain-fill transient), so a run visits
    at least ``2^bits`` distinct configurations; the output neuron (last
    stage) emits one spike to the environment every ``2^bits`` steps.
    """
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    rules = [
        # pacemaker: each neuron relays the clock spike to its twin and
        # into divider stage 0.
        Rule(neuron=0, consume=1, produce=1, regex_base=1, covering=True),
        Rule(neuron=1, consume=1, produce=1, regex_base=1, covering=True),
    ]
    for i in range(bits):
        # divider stage: fire exactly when 2 spikes have accumulated.
        rules.append(Rule(neuron=2 + i, consume=2, produce=1, regex_base=2,
                          covering=False))
    syn = [(0, 1), (1, 0), (0, 2), (1, 2)]
    syn += [(2 + i, 3 + i) for i in range(bits - 1)]
    init = (1, 0) + (0,) * bits
    return SNPSystem(bits + 2, init, tuple(rules), tuple(syn),
                     output_neuron=bits + 1, name=f"counter-{bits}")


def scaled_pi(copies: int, covering: bool = True) -> SNPSystem:
    """``copies`` disjoint instances of the paper's Π as one system.

    Computation tree size grows as (paper tree)^copies; neuron/rule counts
    grow linearly — the natural 'bigger Π' the paper's future-work section
    asks for ("very large systems with equally large matrices").
    """
    from .system import paper_pi

    base = paper_pi(covering=covering)
    m0 = base.num_neurons
    rules = []
    syn = []
    init: Tuple[int, ...] = ()
    for c in range(copies):
        off = c * m0
        for r in base.rules:
            rules.append(dataclasses.replace(r, neuron=r.neuron + off))
        syn += [(i + off, j + off) for (i, j) in base.synapses]
        init = init + tuple(base.initial_spikes)
    return SNPSystem(copies * m0, init, tuple(rules), tuple(syn),
                     output_neuron=copies * m0 - 1,
                     name=f"pi-x{copies}")


# ---------------------------------------------------------------------------
# Large-system families: bounded-degree synapse topologies, O(m·degree)
# construction, for the sparse-backend benchmark tier.
# ---------------------------------------------------------------------------


def _bounded_rules(m: int, rules_per_neuron: int, max_spikes: int,
                   rng: random.Random) -> Tuple[Rule, ...]:
    """Random rules in the same bounded family as :func:`random_system`."""
    rules = []
    for i in range(m):
        for _ in range(rules_per_neuron):
            consume = rng.randint(1, max_spikes)
            rules.append(Rule(
                neuron=i, consume=consume,
                produce=rng.choice([0, 1, 1, 2]),
                regex_base=rng.randint(consume, max_spikes),
                regex_period=rng.choice([0, 0, 1]),
                covering=rng.random() < 0.5,
            ))
    return tuple(rules)


def _sparse_family(name: str, m: int, syn, rules_per_neuron: int,
                   max_spikes: int, seed: int) -> SNPSystem:
    rng = random.Random(seed)
    rules = _bounded_rules(m, rules_per_neuron, max_spikes, rng)
    init = tuple(rng.randint(0, max_spikes) for _ in range(m))
    return SNPSystem(m, init, rules, tuple(syn), output_neuron=m - 1,
                     name=name)


def ring_lattice(m: int, degree: int = 4, rules_per_neuron: int = 2,
                 max_spikes: int = 3, seed: int = 0) -> SNPSystem:
    """Each neuron synapses onto its next ``degree`` ring neighbors:
    exact, uniform out- and in-degree (the best case for ELL packing)."""
    if not 1 <= degree < m:
        raise ValueError(f"need 1 <= degree < m, got degree={degree}, m={m}")
    syn = [(i, (i + d) % m) for i in range(m) for d in range(1, degree + 1)]
    return _sparse_family(f"ring-lattice-{m}d{degree}", m, syn,
                          rules_per_neuron, max_spikes, seed)


def torus(rows: int, cols: Optional[int] = None, rules_per_neuron: int = 2,
          max_spikes: int = 3, seed: int = 0) -> SNPSystem:
    """2-D wrap-around grid, synapses to the 4-neighborhood (degree 4)."""
    cols = rows if cols is None else cols
    if rows < 3 or cols < 3:
        raise ValueError("torus needs rows, cols >= 3 (distinct neighbors)")
    m = rows * cols
    syn = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            syn += [(i, r * cols + (c + 1) % cols),
                    (i, r * cols + (c - 1) % cols),
                    (i, ((r + 1) % rows) * cols + c),
                    (i, ((r - 1) % rows) * cols + c)]
    return _sparse_family(f"torus-{rows}x{cols}", m, syn,
                          rules_per_neuron, max_spikes, seed)


def power_law(m: int, attach: int = 4, rules_per_neuron: int = 2,
              max_spikes: int = 3, seed: int = 0,
              max_in: Optional[int] = None) -> SNPSystem:
    """Preferential attachment (Barabási–Albert): node ``i`` synapses onto
    ``attach`` distinct earlier nodes sampled by degree.  Mean out-degree
    is ``attach``; in-degree is heavy-tailed — the adversarial case for the
    ELL in-adjacency (``K_in`` ≫ mean degree).

    ``max_in=None`` (the default) is the **unbounded-hub** family: the top
    hub's in-degree — hence a pure-ELL ``K_in`` and its padding — grows
    with ``m``, which is exactly the workload the hybrid ELL+COO plan
    (``SystemPlan(encoding="hybrid")``, DESIGN.md §3) exists for; the
    hybrid benchmark tier sweeps this family.  ``max_in`` caps hub
    in-degree (rejection-sampled, with a deterministic fallback scan so a
    saturated pool cannot stall generation — keep ``max_in >= 2·attach``
    to make the fallback rare), bounding ``K_in`` for the pure-ELL tiers.

    Deterministic in ``(m, attach, rules_per_neuron, max_spikes, seed,
    max_in)`` on every Python version: candidate targets are drawn from a
    seeded PRNG and committed in sorted order (never in hash/set order),
    so equal arguments always build the identical system."""
    if not 1 <= attach < m:
        raise ValueError(f"need 1 <= attach < m, got attach={attach}, m={m}")
    if max_in is not None and max_in < attach:
        raise ValueError(f"max_in {max_in} < attach {attach}")
    rng = random.Random(seed ^ 0x5eed)
    syn = []
    in_deg = [0] * m
    # degree-proportional endpoint pool, seeded with a clique of attach+1
    pool = []
    for i in range(attach + 1):
        for j in range(attach + 1):
            if i != j:
                syn.append((i, j))
                pool.append(j)
                in_deg[j] += 1
    for i in range(attach + 1, m):
        targets = set()
        for _ in range(50 * attach):  # bounded rejection sampling
            if len(targets) == attach:
                break
            j = pool[rng.randrange(len(pool))]
            if max_in is None or in_deg[j] < max_in:
                targets.add(j)
        if len(targets) < attach:
            # Near-saturated pool (max_in close to attach), or an extreme
            # hub-dominated pool in the unbounded family: top up from an
            # explicit ascending scan of eligible earlier nodes so
            # generation always terminates, deterministically.
            for j in range(i):
                if len(targets) == attach:
                    break
                if max_in is None or in_deg[j] < max_in:
                    targets.add(j)
            if len(targets) < attach:
                raise ValueError(
                    f"cannot attach {attach} edges under max_in={max_in} "
                    f"at node {i}; raise max_in (>= 2*attach recommended)")
        for j in sorted(targets):
            syn.append((i, j))
            pool.append(j)
            in_deg[j] += 1
        pool.append(i)
    cap = "" if max_in is None else f"c{max_in}"
    return _sparse_family(f"power-law-{m}a{attach}{cap}", m, syn,
                          rules_per_neuron, max_spikes, seed)


# ---------------------------------------------------------------------------
# Delayed variants: every family above gains a semantics="delays" workload
# by injecting per-rule firing delays into an existing system.
# ---------------------------------------------------------------------------


DelaySpec = Union[int, Sequence[int], Callable[[int, Rule], int]]


def with_delays(system: SNPSystem, delays: DelaySpec) -> SNPSystem:
    """A copy of ``system`` whose rules carry firing delays.

    ``delays`` is one of:

    * an ``int`` — every rule gets that delay;
    * a sequence of ``len(system.rules)`` ints — per-rule delays in rule
      order;
    * a callable ``(rule_index, rule) -> int`` — e.g.
      ``lambda k, r: k % 3`` for a deterministic mixed-delay variant.

    The result only compiles under ``SystemPlan(semantics="delays")``
    once any delay is nonzero (``compile_system`` refuses delayed rules
    on the default tier); ``with_delays(sys, 0)`` is a delay-annotated
    system that still runs on either tier and must match ``sys``
    configuration-for-configuration under both."""
    rules = system.rules
    if callable(delays):
        ds = [int(delays(k, r)) for k, r in enumerate(rules)]
    elif isinstance(delays, int):
        ds = [delays] * len(rules)
    else:
        ds = [int(d) for d in delays]
        if len(ds) != len(rules):
            raise ValueError(
                f"delays has {len(ds)} entries, expected one per rule "
                f"({len(rules)})")
    new_rules = tuple(dataclasses.replace(r, delay=d)
                      for r, d in zip(rules, ds))
    suffix = "-delays" if any(ds) else "-delays0"
    return dataclasses.replace(system, rules=new_rules,
                               name=system.name + suffix)
