"""Matrix encoding of an SNP system (paper §2.2), as JAX-ready arrays.

``compile_system`` lowers an :class:`~repro.core.system.SNPSystem` into a
:class:`CompiledSNP` — a pytree of device arrays holding the spiking
transition matrix ``M_Π`` plus per-rule metadata, with rules **sorted by
owning neuron** so per-neuron segment operations are contiguous.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from .system import SNPSystem

__all__ = ["CompiledSNP", "compile_system"]


class CompiledSNP(NamedTuple):
    """Device-array encoding of an SNP system.

    Shapes: ``m`` neurons, ``n`` rules (sorted by neuron).
    """

    M: jnp.ndarray              # (n, m) int32 — spiking transition matrix
    rule_neuron: jnp.ndarray    # (n,)  int32 — owning neuron of each rule
    consume: jnp.ndarray        # (n,)  int32
    produce: jnp.ndarray        # (n,)  int32
    regex_base: jnp.ndarray     # (n,)  int32
    regex_period: jnp.ndarray   # (n,)  int32 (0 => single word)
    covering: jnp.ndarray       # (n,)  bool
    neuron_onehot: jnp.ndarray  # (n, m) int8 — rule->neuron incidence
    env_produce: jnp.ndarray    # (n,)  int32 — spikes emitted to environment
    init_config: jnp.ndarray    # (m,)  int32 — C_0
    rule_order: Tuple[int, ...]  # original rule index per sorted position

    @property
    def num_rules(self) -> int:
        return self.M.shape[0]

    @property
    def num_neurons(self) -> int:
        return self.M.shape[1]


def compile_system(system: SNPSystem) -> CompiledSNP:
    m, n = system.num_neurons, system.num_rules
    if n == 0:
        raise ValueError("system has no rules")

    # Stable sort rules by neuron, remembering the original total order so
    # spiking vectors can be reported in the paper's ordering.
    order = sorted(range(n), key=lambda i: system.rules[i].neuron)
    rules = [system.rules[i] for i in order]

    syn = set(system.synapses)
    M = np.zeros((n, m), dtype=np.int32)
    for i, r in enumerate(rules):
        M[i, r.neuron] = -r.consume
        if r.produce > 0:
            for j in range(m):
                if (r.neuron, j) in syn:
                    M[i, j] = r.produce

    rule_neuron = np.array([r.neuron for r in rules], dtype=np.int32)
    env_produce = np.array(
        [r.produce if r.neuron == system.output_neuron else 0 for r in rules],
        dtype=np.int32,
    )
    onehot = np.zeros((n, m), dtype=np.int8)
    onehot[np.arange(n), rule_neuron] = 1

    return CompiledSNP(
        M=jnp.asarray(M),
        rule_neuron=jnp.asarray(rule_neuron),
        consume=jnp.asarray([r.consume for r in rules], dtype=jnp.int32),
        produce=jnp.asarray([r.produce for r in rules], dtype=jnp.int32),
        regex_base=jnp.asarray([r.regex_base for r in rules], dtype=jnp.int32),
        regex_period=jnp.asarray([r.regex_period for r in rules], dtype=jnp.int32),
        covering=jnp.asarray([r.covering for r in rules], dtype=bool),
        neuron_onehot=jnp.asarray(onehot),
        env_produce=jnp.asarray(env_produce),
        init_config=jnp.asarray(system.initial_spikes, dtype=jnp.int32),
        rule_order=tuple(order),
    )
