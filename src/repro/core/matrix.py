"""Matrix encodings of an SNP system (paper §2.2), as JAX-ready arrays.

Two lowerings of an :class:`~repro.core.system.SNPSystem`, both with rules
**sorted by owning neuron** so per-neuron segment operations are contiguous:

* :func:`compile_system` — the paper's dense spiking transition matrix
  ``M_Π`` (:class:`CompiledSNP`); ``O(n·m)`` memory, exact match for the
  paper's eq. 2 formulation.
* :func:`compile_system_sparse` — an ELL/segment encoding
  (:class:`CompiledSparseSNP`) that never materializes ``M_Π``: per-rule
  ELL-packed column indices/values (width = the *measured*
  ``max_nnz_per_rule``), per-neuron rule segments, and the ELL-packed
  in-adjacency of the synapse graph.  Real SNP graphs have bounded synapse
  out-degree, so ``nnz(M_Π) = O(n·degree)`` while the dense matrix is
  ``O(n·m)`` — the sparse step backends (``"sparse"``, ``"sparse_pallas"``)
  run on this encoding in ``O(B·T·m·degree)`` instead of ``O(B·T·n·m)``.
  With ``hub_threshold=H`` (requested through a
  :class:`~repro.core.plan.SystemPlan` with ``encoding="hybrid"``) the ELL
  in-adjacency is capped at ``H`` entries per neuron and the tail synapses
  of hub neurons spill into a COO segment (``coo_src``/``coo_dst``,
  combined by segment-sum) — exact, and no padding blow-up on heavy-tailed
  graphs (power-law without ``max_in``).  Layout details in DESIGN.md §3.

Both compilers build their arrays from vectorized numpy adjacency indexing
(no per-rule × per-neuron Python loops), so systems with ``m >= 10^4``
neurons compile in well under a second.
"""

from __future__ import annotations

from typing import List, NamedTuple, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .system import Rule, SNPSystem

__all__ = [
    "CompiledSNP",
    "CompiledSparseSNP",
    "CompiledAny",
    "compile_system",
    "compile_system_sparse",
    "is_compiled",
    "is_delayed",
]

_SEMANTICS = ("no_delays", "delays")


def _check_semantics(system: SNPSystem, semantics: str) -> bool:
    """Validate the semantics axis at compile time; returns ``True`` for
    the delayed tier.  Compiling a system that carries nonzero delays
    under the paper's ``no_delays`` semantics raises — the delays would
    silently be ignored otherwise."""
    if semantics not in _SEMANTICS:
        raise ValueError(
            f"semantics must be one of {_SEMANTICS}, got {semantics!r}")
    if semantics == "no_delays" and system.max_delay > 0:
        raise ValueError(
            f"system {system.name!r} has rules with delay > 0; compile it "
            "under SystemPlan(semantics=\"delays\") (the paper's matrix "
            "semantics is delay-free)")
    return semantics == "delays"


class CompiledSNP(NamedTuple):
    """Dense device-array encoding of an SNP system.

    Shapes: ``m`` neurons, ``n`` rules (sorted by neuron).

    The trailing delay fields are ``None`` under the default
    ``no_delays`` semantics (the historical encoding, bit-identical to
    pre-delay builds); ``SystemPlan(semantics="delays")`` populates them
    and widens ``init_config`` to the ``3m`` state layout
    ``[spikes | countdown | pending]`` (DESIGN.md §2 "Delayed semantics").
    """

    M: jnp.ndarray              # (n, m) int32 — spiking transition matrix
    rule_neuron: jnp.ndarray    # (n,)  int32 — owning neuron of each rule
    consume: jnp.ndarray        # (n,)  int32
    produce: jnp.ndarray        # (n,)  int32
    regex_base: jnp.ndarray     # (n,)  int32
    regex_period: jnp.ndarray   # (n,)  int32 (0 => single word)
    covering: jnp.ndarray       # (n,)  bool
    neuron_onehot: jnp.ndarray  # (n, m) int8 — rule->neuron incidence
    env_produce: jnp.ndarray    # (n,)  int32 — spikes emitted to environment
    init_config: jnp.ndarray    # (m,) int32 — C_0 (3m under delays)
    rule_order: Tuple[int, ...]  # original rule index per sorted position
    # -- delayed-semantics extension (None == no_delays encoding) ---------
    delay: jnp.ndarray = None       # (n,) int32 — per-rule firing delay
    adjacency: jnp.ndarray = None   # (m, m) int32 — 0/1 synapse matrix
    #   (src, dst); carries reopening neurons' pending spikes to their
    #   out-neighbors, which M's per-rule rows cannot express.
    out_neuron: jnp.ndarray = None  # () int32 — output neuron, or m if
    #   none; under delays env emission is the *emit-now* amount at this
    #   neuron (time-shifted by d), not the per-rule env_produce.

    @property
    def num_rules(self) -> int:
        return self.M.shape[0]

    @property
    def num_neurons(self) -> int:
        return self.M.shape[1]

    @property
    def state_width(self) -> int:
        """Columns of one configuration row: ``m``, or ``3m`` under the
        delayed semantics (``[spikes | countdown | pending]``)."""
        return self.init_config.shape[0]


class CompiledSparseSNP(NamedTuple):
    """ELL/segment device-array encoding of an SNP system — no ``O(n·m)``
    arrays anywhere (DESIGN.md §3).

    Shapes: ``m`` neurons, ``n`` rules (sorted by neuron), ``K`` =
    ``max_nnz_per_rule`` (measured at compile time), ``R`` =
    ``max_rules_per_neuron``, ``Kin`` = max synapse in-degree (>= 1).

    Padding convention: index entries beyond a row's real length point at
    the out-of-range id (neuron ``m`` / rule ``n``); every consumer gathers
    through a zero-extended table so padding contributes exactly 0.
    """

    # -- per-rule metadata (identical convention to CompiledSNP) ----------
    rule_neuron: jnp.ndarray    # (n,)  int32
    consume: jnp.ndarray        # (n,)  int32
    produce: jnp.ndarray        # (n,)  int32
    regex_base: jnp.ndarray     # (n,)  int32
    regex_period: jnp.ndarray   # (n,)  int32
    covering: jnp.ndarray       # (n,)  bool
    env_produce: jnp.ndarray    # (n,)  int32
    init_config: jnp.ndarray    # (m,)  int32
    out_neuron: jnp.ndarray     # ()    int32 — output neuron, or m if none
    rule_order: Tuple[int, ...]
    # -- per-neuron rule segments (rules are neuron-sorted) ---------------
    seg_start: jnp.ndarray      # (m,) int32 — first rule index of neuron
    seg_count: jnp.ndarray      # (m,) int32 — #rules owned by neuron
    rule_slots: jnp.ndarray     # (R,) int32 == arange(R); carries R in its
    #                             shape so traced code can size tables
    # -- ELL rows of M_Π ---------------------------------------------------
    ell_col: jnp.ndarray        # (n, K) int32 — column (target neuron), pad m
    ell_val: jnp.ndarray        # (n, K) int32 — value, pad 0
    ell_nnz: jnp.ndarray        # (n,)  int32 — real row lengths
    # -- ELL in-adjacency of the synapse graph ----------------------------
    in_idx: jnp.ndarray         # (m, Kin) int32 — in-neighbors, pad m
    # -- COO tail of the in-adjacency (hybrid encoding; empty for pure ELL)
    coo_src: jnp.ndarray        # (Ec,) int32 — tail in-neighbor
    coo_dst: jnp.ndarray        # (Ec,) int32 — tail target neuron (sorted)
    # -- COO lowering metadata: the scatter-free segment-sum form the fused
    #    kernel consumes (DESIGN.md §3 "Kernel lowering").  ``coo_dst`` is
    #    sorted, so each hub's tail is one contiguous run: hub ``h`` owns
    #    entries ``coo_bounds[h]:coo_bounds[h+1]`` and a neuron maps to its
    #    hub via ``hub_slot`` (``Hn`` = no tail, the zero slot).  ``None``
    #    only on hand-built encodings that skipped the compiler — the
    #    kernel refuses those instead of silently downgrading.
    coo_bounds: jnp.ndarray = None   # (Hn+1,) int32 — per-hub tail offsets
    hub_slot: jnp.ndarray = None     # (m,) int32 — neuron -> hub index or Hn
    # -- delayed-semantics extension (None == no_delays encoding) ---------
    delay: jnp.ndarray = None        # (n,) int32 — per-rule firing delay
    #   The reopen-pending fanout reuses in_idx/COO (the same in-adjacency
    #   the fired produce rides), so no extra adjacency array is needed.

    @property
    def num_rules(self) -> int:
        return self.rule_neuron.shape[0]

    @property
    def num_neurons(self) -> int:
        return self.seg_start.shape[0]

    @property
    def state_width(self) -> int:
        """Columns of one configuration row: ``m``, or ``3m`` under the
        delayed semantics (``[spikes | countdown | pending]``)."""
        return self.init_config.shape[0]

    @property
    def max_nnz_per_rule(self) -> int:
        return self.ell_col.shape[1]

    @property
    def max_rules_per_neuron(self) -> int:
        return self.rule_slots.shape[0]

    @property
    def max_in_degree(self) -> int:
        return self.in_idx.shape[1]

    @property
    def is_hybrid(self) -> bool:
        """True when the in-adjacency carries a COO tail (hybrid plan)."""
        return self.coo_src.shape[0] > 0

    @property
    def in_adjacency_slots(self) -> int:
        """Total in-adjacency storage slots (ELL padding included) — the
        quantity the hybrid split minimizes on heavy-tailed graphs."""
        return self.in_idx.size + self.coo_src.shape[0]


CompiledAny = Union[CompiledSNP, CompiledSparseSNP]


def is_compiled(obj) -> bool:
    """True for any compiled encoding (dense or sparse)."""
    return isinstance(obj, (CompiledSNP, CompiledSparseSNP))


def is_delayed(comp) -> bool:
    """True when ``comp`` was compiled under the delayed semantics tier
    (its per-rule delay vector is populated and its configuration rows
    carry the ``[spikes | countdown | pending]`` layout)."""
    return getattr(comp, "delay", None) is not None


# ---------------------------------------------------------------------------
# shared numpy lowering helpers
# ---------------------------------------------------------------------------


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """``concatenate([arange(c) for c in counts])`` without the Python loop."""
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros((0,), np.int64)
    starts = np.cumsum(counts) - counts
    return np.arange(total) - np.repeat(starts, counts)


class _Lowered(NamedTuple):
    """Neuron-sorted rule arrays + synapse adjacency, all numpy."""

    order: Tuple[int, ...]
    rules: List[Rule]
    neuron: np.ndarray        # (n,) i32
    consume: np.ndarray       # (n,) i32
    produce: np.ndarray       # (n,) i32
    regex_base: np.ndarray
    regex_period: np.ndarray
    covering: np.ndarray      # (n,) bool
    env_produce: np.ndarray   # (n,) i32
    src: np.ndarray           # (E,) i32 — synapse sources, sorted by (src,dst)
    dst: np.ndarray           # (E,) i32
    out_deg: np.ndarray       # (m,) i64
    out_start: np.ndarray     # (m,) i64 — CSR row starts into src/dst


def _lower(system: SNPSystem) -> _Lowered:
    m, n = system.num_neurons, system.num_rules
    if n == 0:
        raise ValueError("system has no rules")

    # Stable sort rules by neuron, remembering the original total order so
    # spiking vectors can be reported in the paper's ordering.
    neuron0 = np.fromiter((r.neuron for r in system.rules), np.int64, n)
    order = np.argsort(neuron0, kind="stable")
    rules = [system.rules[i] for i in order]

    neuron = neuron0[order].astype(np.int32)
    consume = np.fromiter((r.consume for r in rules), np.int32, n)
    produce = np.fromiter((r.produce for r in rules), np.int32, n)
    regex_base = np.fromiter((r.regex_base for r in rules), np.int32, n)
    regex_period = np.fromiter((r.regex_period for r in rules), np.int32, n)
    covering = np.fromiter((r.covering for r in rules), bool, n)
    env_produce = np.where(neuron == system.output_neuron, produce, 0) \
        .astype(np.int32)

    syn = np.asarray(system.synapses, np.int64).reshape(-1, 2)
    o = np.lexsort((syn[:, 1], syn[:, 0]))
    src, dst = syn[o, 0], syn[o, 1]
    out_deg = np.bincount(src, minlength=m)
    out_start = np.cumsum(out_deg) - out_deg

    return _Lowered(order=tuple(int(i) for i in order), rules=rules,
                    neuron=neuron, consume=consume, produce=produce,
                    regex_base=regex_base, regex_period=regex_period,
                    covering=covering, env_produce=env_produce,
                    src=src.astype(np.int32), dst=dst.astype(np.int32),
                    out_deg=out_deg, out_start=out_start)


def _rule_row_entries(low: _Lowered):
    """Flat (rule, column, value) triples of the produce entries of M_Π.

    Rule ``i`` (neuron-sorted) with ``produce > 0`` writes ``produce`` into
    every out-neighbor column of its neuron; the consume entry (its own
    neuron, value ``-consume``) is handled separately by each caller.
    Returns ``(rows, pos, cols, vals)`` with ``pos`` the within-row slot.
    """
    n = low.neuron.shape[0]
    prod_rules = np.nonzero(low.produce > 0)[0]
    deg_r = low.out_deg[low.neuron[prod_rules]]
    rows = np.repeat(prod_rules, deg_r)
    pos = _ragged_arange(deg_r)
    flat = np.repeat(low.out_start[low.neuron[prod_rules]], deg_r) + pos
    cols = low.dst[flat] if rows.size else np.zeros((0,), np.int32)
    vals = np.repeat(low.produce[prod_rules], deg_r)
    return rows.astype(np.int64), pos, cols, vals.astype(np.int32), \
        prod_rules, deg_r


def _delay_vector(low: _Lowered) -> np.ndarray:
    return np.fromiter((r.delay for r in low.rules), np.int32,
                       len(low.rules))


def _widened_init(system: SNPSystem) -> np.ndarray:
    """``[spikes | countdown | pending]`` initial state: every neuron
    starts open with nothing pending."""
    m = system.num_neurons
    out = np.zeros((3 * m,), np.int32)
    out[:m] = system.initial_spikes
    return out


def compile_system(system: SNPSystem, *,
                   semantics: str = "no_delays") -> CompiledSNP:
    """Dense lowering (paper eq. 1).  Fully vectorized: the dense ``M`` is
    built by adjacency indexing, not an ``O(n·m)`` synapse-set scan.

    ``semantics="delays"`` additionally emits the per-rule delay vector,
    the 0/1 synapse adjacency (reopen-pending fanout), and the widened
    ``3m`` initial state (DESIGN.md §2 "Delayed semantics")."""
    delayed = _check_semantics(system, semantics)
    m, n = system.num_neurons, system.num_rules
    low = _lower(system)

    M = np.zeros((n, m), dtype=np.int32)
    M[np.arange(n), low.neuron] = -low.consume
    rows, _, cols, vals, _, _ = _rule_row_entries(low)
    M[rows, cols] = vals  # no collisions: self-synapses are forbidden

    onehot = np.zeros((n, m), dtype=np.int8)
    onehot[np.arange(n), low.neuron] = 1

    extra = {}
    if delayed:
        adj = np.zeros((m, m), np.int32)
        adj[low.src, low.dst] = 1
        extra = dict(
            delay=jnp.asarray(_delay_vector(low)),
            adjacency=jnp.asarray(adj),
            out_neuron=jnp.asarray(
                system.output_neuron if system.output_neuron >= 0 else m,
                dtype=jnp.int32))
    init = _widened_init(system) if delayed \
        else np.asarray(system.initial_spikes, np.int32)

    return CompiledSNP(
        M=jnp.asarray(M),
        rule_neuron=jnp.asarray(low.neuron),
        consume=jnp.asarray(low.consume),
        produce=jnp.asarray(low.produce),
        regex_base=jnp.asarray(low.regex_base),
        regex_period=jnp.asarray(low.regex_period),
        covering=jnp.asarray(low.covering),
        neuron_onehot=jnp.asarray(onehot),
        env_produce=jnp.asarray(low.env_produce),
        init_config=jnp.asarray(init, dtype=jnp.int32),
        rule_order=low.order,
        **extra,
    )


def compile_system_sparse(system: SNPSystem, *,
                          hub_threshold: int | None = None,
                          semantics: str = "no_delays"
                          ) -> CompiledSparseSNP:
    """Sparse lowering: ELL rows of ``M_Π`` + per-neuron segments + ELL
    in-adjacency.  Never allocates anything ``O(n·m)``; memory and compile
    time are ``O(n·K + m·Kin)`` with measured widths.

    ``hub_threshold=H`` selects the **hybrid** in-adjacency: the ELL part
    is capped at ``H`` entries per neuron and every further in-synapse of a
    hub neuron lands in the COO tail (``coo_src``/``coo_dst``, sorted by
    ``(dst, src)``), so heavy-tailed graphs stop paying ``m·Kin`` padding
    for one hub.  ``None`` (default) is the pure-ELL layout, bit-identical
    to the pre-plan encoding.  Callers normally reach this through
    ``backend.compile(system, plan=...)`` (DESIGN.md §3).

    ``semantics="delays"`` emits the per-rule delay vector and the
    widened ``3m`` initial state; the reopen-pending fanout rides the
    same ELL/COO in-adjacency as the fired produce, so the layout gains
    no new index arrays (DESIGN.md §2 "Delayed semantics")."""
    delayed = _check_semantics(system, semantics)
    m, n = system.num_neurons, system.num_rules
    low = _lower(system)

    # The sparse step packs (produce, consume) of a fired rule into one
    # int32 (produce | consume << 16) so the hot per-branch lookup is a
    # single gather; bounds far beyond any simulable system (spike counts
    # must stay < 2^24 anyway, DESIGN.md §2).
    if int(low.produce.max(initial=0)) >= 1 << 16 \
            or int(low.consume.max(initial=0)) >= 1 << 15:
        raise ValueError("sparse encoding requires produce < 2^16 and "
                         "consume < 2^15 per rule")

    # -- per-neuron rule segments -----------------------------------------
    seg_count = np.bincount(low.neuron, minlength=m).astype(np.int32)
    seg_start = (np.cumsum(seg_count) - seg_count).astype(np.int32)
    R = int(max(seg_count.max(), 1))

    # -- ELL rows of M: slot 0 is the consume entry, 1.. the produce fanout
    rows, pos, cols, vals, prod_rules, deg_r = _rule_row_entries(low)
    K = int(1 + (deg_r.max() if deg_r.size else 0))
    ell_col = np.full((n, K), m, dtype=np.int32)
    ell_val = np.zeros((n, K), dtype=np.int32)
    ell_col[:, 0] = low.neuron
    ell_val[:, 0] = -low.consume
    ell_col[rows, 1 + pos] = cols
    ell_val[rows, 1 + pos] = vals
    ell_nnz = np.ones((n,), np.int32)
    ell_nnz[prod_rules] += deg_r.astype(np.int32)

    # -- ELL in-adjacency (transposed synapse graph) ----------------------
    # Entries sorted by (target, source); a ragged arange over the in-degree
    # histogram yields each entry's slot within its target's row.  With a
    # hub threshold, slots >= threshold spill to the COO tail (still in
    # (target, source) order, so the split is deterministic).
    in_deg = np.bincount(low.dst, minlength=m)
    kin_full = int(max(in_deg.max() if in_deg.size else 0, 1))
    if hub_threshold is not None and hub_threshold < 1:
        raise ValueError(f"hub_threshold must be >= 1, got {hub_threshold}")
    Kin = kin_full if hub_threshold is None else min(kin_full,
                                                    int(hub_threshold))
    o = np.lexsort((low.src, low.dst))
    slot = _ragged_arange(in_deg)
    ell_part = slot < Kin
    in_idx = np.full((m, Kin), m, dtype=np.int32)
    in_idx[low.dst[o][ell_part], slot[ell_part]] = low.src[o][ell_part]
    coo_src = low.src[o][~ell_part].astype(np.int32)
    coo_dst = low.dst[o][~ell_part].astype(np.int32)

    # COO segment metadata (kernel lowering, DESIGN.md §3): coo_dst is
    # (dst, src)-sorted, so each hub's tail is one contiguous run.
    hubs, hub_counts = np.unique(coo_dst, return_counts=True)
    hn = hubs.shape[0]
    coo_bounds = np.zeros((hn + 1,), np.int32)
    np.cumsum(hub_counts, out=coo_bounds[1:])
    hub_slot = np.full((m,), hn, np.int32)
    hub_slot[hubs] = np.arange(hn, dtype=np.int32)

    init = _widened_init(system) if delayed \
        else np.asarray(system.initial_spikes, np.int32)
    return CompiledSparseSNP(
        rule_neuron=jnp.asarray(low.neuron),
        consume=jnp.asarray(low.consume),
        produce=jnp.asarray(low.produce),
        regex_base=jnp.asarray(low.regex_base),
        regex_period=jnp.asarray(low.regex_period),
        covering=jnp.asarray(low.covering),
        env_produce=jnp.asarray(low.env_produce),
        init_config=jnp.asarray(init, dtype=jnp.int32),
        out_neuron=jnp.asarray(
            system.output_neuron if system.output_neuron >= 0 else m,
            dtype=jnp.int32),
        rule_order=low.order,
        seg_start=jnp.asarray(seg_start),
        seg_count=jnp.asarray(seg_count),
        rule_slots=jnp.arange(R, dtype=jnp.int32),
        ell_col=jnp.asarray(ell_col),
        ell_val=jnp.asarray(ell_val),
        ell_nnz=jnp.asarray(ell_nnz),
        in_idx=jnp.asarray(in_idx),
        coo_src=jnp.asarray(coo_src),
        coo_dst=jnp.asarray(coo_dst),
        coo_bounds=jnp.asarray(coo_bounds),
        hub_slot=jnp.asarray(hub_slot),
        delay=jnp.asarray(_delay_vector(low)) if delayed else None,
    )
