"""Pure-jnp reference semantics for batched SNP simulation.

This is the mathematical core of the paper, vectorized over a *frontier*
of ``B`` configurations at once:

* applicability mask over rules            (paper Alg. 2, step II-1)
* mixed-radix rank-decode of every valid
  spiking vector — replaces the paper's
  host-side string enumeration             (paper Alg. 2, steps II-2/II-3)
* the affine transition ``C' = C + S·M``   (paper eq. 2)

Everything here is shape-static and jit/vmap/shard_map friendly.  The fused
Pallas TPU kernel (``repro.kernels.snp_step``) implements the same math with
explicit VMEM tiling; this module doubles as its oracle (``ref.py``).
The sparse twins (:func:`sparse_branch_info`, :func:`sparse_next_configs`)
run the same math on the ELL/segment encoding
(:class:`~repro.core.matrix.CompiledSparseSNP`) in ``O(B·T·nnz)`` with
bit-identical valid entries — see DESIGN.md §3.

Enumeration order.  Neuron 0 is the most-significant mixed-radix digit:
branch index ``t ∈ [0, Ψ)`` decodes to ``digit_i = (t // stride_i) % k_i``
with ``stride_i = Π_{j>i} k_j``, where ``k_i = max(1, #applicable rules in
neuron i)``.  Within a neuron, digit ``d`` selects the ``d``-th applicable
rule in the total order.  This enumerates exactly the Ψ valid spiking
vectors of Alg. 2 — by construction, no generate-and-filter.

Overflow discipline.  Ψ can be astronomically large; all radix products are
computed in float32, which saturates monotonically (exact for products below
2^24, +inf beyond) — see DESIGN.md §2.  Whenever ``Ψ > max_branches`` the
config is flagged in ``branch_overflow`` and only the first ``max_branches``
branches (a valid, deterministic subset) are produced.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .matrix import CompiledAny, CompiledSNP, CompiledSparseSNP

__all__ = [
    "applicability",
    "branch_info",
    "sparse_branch_info",
    "packed_rule_table",
    "spiking_vectors",
    "next_configs",
    "sparse_next_configs",
    "StepOut",
    "split_state",
    "delayed_branch_info",
    "sparse_delayed_branch_info",
    "delayed_weight_matrix",
    "delayed_packed_actions",
    "delayed_next_configs",
    "sparse_delayed_next_configs",
]


def applicability(config: jnp.ndarray, comp: CompiledAny) -> jnp.ndarray:
    """Boolean mask (..., n): which rules may fire at ``config`` (..., m).

    A rule with regex ``{b + t·p}`` is applicable at ``s`` spikes iff

    * exact mode:    ``s >= b`` and (``p == 0`` ? ``s == b``
                     : ``(s - b) % p == 0``)
    * covering mode: ``s >= b``  (the paper's (b-3) ``>=`` threshold;
                     with ``p > 0`` membership is against ``{b+t·p}``'s
                     downward closure, i.e. still just ``s >= b``)

    and always ``s >= consume``.
    """
    s = jnp.take(config, comp.rule_neuron, axis=-1)  # (..., n) spikes at owner
    ge_base = s >= comp.regex_base
    diff = s - comp.regex_base
    on_progression = jnp.where(
        comp.regex_period > 0,
        (diff % jnp.maximum(comp.regex_period, 1)) == 0,
        s == comp.regex_base,
    )
    member = jnp.where(comp.covering, ge_base, ge_base & on_progression)
    return member & (s >= comp.consume)


class BranchInfo(NamedTuple):
    app: jnp.ndarray        # (..., n) bool
    rank: jnp.ndarray       # (..., n) int32 — index among applicable in neuron
    choices: jnp.ndarray    # (..., m) int32 — max(1, #applicable)
    stride: jnp.ndarray     # (..., m) float32 — Π_{j>i} choices_j (exact < 2^24)
    psi: jnp.ndarray        # (...,)  float32 — Ψ (saturating)
    alive: jnp.ndarray      # (...,)  bool — any rule applicable at all


def branch_info(config: jnp.ndarray, comp: CompiledSNP) -> BranchInfo:
    return _branch_info_from_app(applicability(config, comp), comp)


def _branch_info_from_app(app: jnp.ndarray, comp: CompiledSNP) -> BranchInfo:
    app_i = app.astype(jnp.int32)
    onehot = comp.neuron_onehot.astype(jnp.int32)  # (n, m)

    # #applicable per neuron, and per-rule rank among the applicable rules of
    # its own neuron.  Rules are neuron-sorted, so an inclusive cumsum minus
    # the neuron's exclusive prefix gives the within-neuron rank.
    k = app_i @ onehot                       # (..., m)
    incl = jnp.cumsum(app_i, axis=-1)        # (..., n)
    # exclusive prefix at each rule's neuron start: total applicable in all
    # earlier neurons = sum over neurons j < neuron(i) of k_j.
    k_prefix = jnp.cumsum(k, axis=-1) - k    # (..., m) exclusive over neurons
    start = jnp.take_along_axis(
        k_prefix,
        jnp.broadcast_to(comp.rule_neuron, app.shape).astype(jnp.int32),
        axis=-1,
    )
    rank = incl - start - 1                  # valid where app

    choices = jnp.maximum(k, 1)
    cf = choices.astype(jnp.float32)
    # stride_i = Π_{j > i} choices_j ; suffix products via reversed cumprod.
    suffix = jnp.cumprod(cf[..., ::-1], axis=-1)[..., ::-1]  # Π_{j >= i}
    psi = suffix[..., 0]
    stride = jnp.concatenate(
        [suffix[..., 1:], jnp.ones_like(cf[..., :1])], axis=-1
    )
    alive = jnp.any(app, axis=-1)
    return BranchInfo(app=app, rank=rank, choices=choices, stride=stride,
                      psi=psi, alive=alive)


def spiking_vectors(
    config: jnp.ndarray, comp: CompiledSNP, max_branches: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All valid spiking vectors at ``config``.

    Returns ``(S, valid, overflow)`` with ``S``: (..., T, n) int32 in
    **neuron-sorted rule order** (use ``comp.rule_order`` to map back to the
    paper's total order), ``valid``: (..., T) bool, ``overflow``: (...,) bool.
    Dead configs (no applicable rule) produce no valid branches.
    """
    return _decode_spiking(branch_info(config, comp), comp, max_branches)


def _decode_spiking(
    info: BranchInfo, comp: CompiledSNP, max_branches: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    T = max_branches
    t = jnp.arange(T, dtype=jnp.int32)

    # Mixed-radix decode directly in *rule space*: gather each rule's
    # neuron-stride/choice first ((..., n) tensors), then decode per branch.
    # This skips the (..., T, m) digit tensor and the (..., T, n) gather —
    # ~25% less HBM traffic on wide systems (EXPERIMENTS.md §Perf cell C).
    # Strides are exact in float32 whenever Ψ <= T (see module docstring);
    # clamp before casting so saturated strides stay valid int32 (yielding
    # digit 0: a legal choice).
    stride_i = jnp.minimum(info.stride, 2.0 ** 30).astype(jnp.int32)
    rule_idx = comp.rule_neuron.astype(jnp.int32)
    stride_r = jnp.take(stride_i, rule_idx, axis=-1)      # (..., n)
    choices_r = jnp.take(info.choices, rule_idx, axis=-1)  # (..., n)
    digits_r = (
        t[:, None] // stride_r[..., None, :]
    ) % choices_r[..., None, :]                            # (..., T, n)
    S = (
        info.app[..., None, :]
        & (digits_r == info.rank[..., None, :])
    ).astype(jnp.int32)

    valid = (t.astype(jnp.float32) < info.psi[..., None]) & info.alive[..., None]
    overflow = info.psi > float(T)
    return S, valid, overflow


class StepOut(NamedTuple):
    configs: jnp.ndarray    # (..., T, m) int32 — successor configurations
    valid: jnp.ndarray      # (..., T) bool
    emissions: jnp.ndarray  # (..., T) int32 — spikes sent to the environment
    overflow: jnp.ndarray   # (...,) bool — Ψ exceeded max_branches
    spiking: jnp.ndarray    # (..., T, n) int32 — the spiking vectors used


def next_configs(
    config: jnp.ndarray, comp: CompiledSNP, max_branches: int
) -> StepOut:
    """One synchronous SNP step: every successor of every config.

    ``C' = C + S · M_Π`` (paper eq. 2), batched over leading dims and over
    all ``T = max_branches`` candidate branches.
    """
    S, valid, overflow = spiking_vectors(config, comp, max_branches)
    # f32 matmul is exact for |values| < 2^24 and maps onto the MXU on TPU;
    # spike counts beyond 2^24 are out of scope (would overflow int32 fast).
    delta = jnp.einsum(
        "...tn,nm->...tm", S.astype(jnp.float32), comp.M.astype(jnp.float32)
    ).astype(jnp.int32)
    out = config[..., None, :] + delta
    emissions = jnp.einsum(
        "...tn,n->...t", S.astype(jnp.float32),
        comp.env_produce.astype(jnp.float32),
    ).astype(jnp.int32)
    return StepOut(configs=out, valid=valid, emissions=emissions,
                   overflow=overflow, spiking=S)


# ---------------------------------------------------------------------------
# Sparse path: the same math on the ELL/segment encoding, O(B·T·m·degree)
# instead of O(B·T·n·m) — see DESIGN.md §3.
# ---------------------------------------------------------------------------


def sparse_branch_info(config: jnp.ndarray,
                       comp: CompiledSparseSNP) -> BranchInfo:
    """:func:`branch_info` on the sparse encoding — bit-identical outputs.

    Per-neuron applicable counts come from a prefix-sum difference over the
    neuron-sorted rule axis (a segment sum over ``seg_start``/``seg_count``)
    instead of the dense ``app @ neuron_onehot`` matmul; ranks reuse the
    same inclusive-cumsum trick.  The float32 stride/Ψ products are the
    *same operations in the same order* as the dense path, so overflow
    saturation matches exactly (DESIGN.md §2).
    """
    return _sparse_info_from_app(applicability(config, comp), comp)


def _sparse_info_from_app(app: jnp.ndarray,
                          comp: CompiledSparseSNP) -> BranchInfo:
    app_i = app.astype(jnp.int32)
    incl = jnp.cumsum(app_i, axis=-1)                        # (..., n)
    cum0 = jnp.concatenate(
        [jnp.zeros_like(incl[..., :1]), incl], axis=-1)      # (..., n+1)
    start = jnp.take(cum0, comp.seg_start, axis=-1)          # (..., m)
    k = jnp.take(cum0, comp.seg_start + comp.seg_count, axis=-1) - start
    rank = incl - jnp.take(start, comp.rule_neuron, axis=-1) - 1

    choices = jnp.maximum(k, 1)
    cf = choices.astype(jnp.float32)
    suffix = jnp.cumprod(cf[..., ::-1], axis=-1)[..., ::-1]
    psi = suffix[..., 0]
    stride = jnp.concatenate(
        [suffix[..., 1:], jnp.ones_like(cf[..., :1])], axis=-1)
    alive = jnp.any(app, axis=-1)
    return BranchInfo(app=app, rank=rank, choices=choices, stride=stride,
                      psi=psi, alive=alive)


def packed_rule_table(info: BranchInfo, comp: CompiledSparseSNP,
                      packed: jnp.ndarray = None) -> jnp.ndarray:
    """``tab`` (..., m, R) int32: ``produce | consume << 16`` of the d-th
    applicable rule of neuron μ at slot ``[..., μ, d]``, 0 where there is
    none.  ``O(B·m·R²)`` per *config* (not per branch), built scatter-free:
    static-index gathers pull each segment's ≤ R rules side by side, a tiny
    cumsum ranks the applicable ones, and an unrolled R² select places each
    at its rank slot (XLA scatters cost ~50x a gathered element on CPU; R
    is small by construction).  The packing (bounds checked by
    ``compile_system_sparse``) makes the hot per-branch fired-rule lookup a
    single gather instead of one per attribute.

    ``packed`` overrides the per-rule (n,) int32 payload (the delayed tier
    routes its own action packings through the same rank machinery)."""
    n = comp.num_rules
    m = comp.num_neurons
    R = comp.rule_slots.shape[0]
    batch = info.app.shape[:-1]
    app = info.app.reshape(-1, n)
    B = app.shape[0]
    slots = comp.rule_slots                                  # (R,) arange
    seg_idx = jnp.minimum(
        comp.seg_start[:, None] + slots[None, :], n - 1)     # (m, R)
    in_seg = slots[None, :] < comp.seg_count[:, None]        # (m, R)
    if packed is None:
        packed = comp.produce | (comp.consume << 16)         # (n,)
    packed_s = jnp.where(in_seg, jnp.take(packed, seg_idx, axis=0), 0)
    app_s = jnp.take(
        app, seg_idx.reshape(-1), axis=-1).reshape(B, m, R) & in_seg
    # rank of slot j within its segment = #applicable among slots <= j, - 1
    dd = jnp.cumsum(app_s.astype(jnp.int32), axis=-1) - 1    # (B, m, R)
    cols = [
        jnp.where(app_s & (dd == d), packed_s[None], 0).sum(axis=-1)
        for d in range(R)
    ]
    return jnp.stack(cols, axis=-1).reshape(*batch, m, R)


def _decode_digits(t: jnp.ndarray, info: BranchInfo) -> jnp.ndarray:
    """Mixed-radix digit per (branch, neuron): ``(t // stride) % choices``
    as (..., T, m) int32, computed in float32.

    Integer division does not vectorize on CPU (and costs ~20x a float op);
    f32 division is *exact* here: with ``j = floor(t/stride)``, a wrong
    floor needs the true quotient within ulp(j)/2 ≤ 2^-23·j of an integer
    from below, but it sits at least ``1/stride ≥ j/T`` away — impossible
    for ``T < 2^23``.  Saturated (+inf) strides quotient to 0, matching the
    dense path's clamped-int division.  Same argument for the modulus.
    """
    tf = t.astype(jnp.float32).reshape((1,) * (info.stride.ndim - 1) + (-1, 1))
    s = info.stride[..., None, :]
    c = info.choices.astype(jnp.float32)[..., None, :]
    q = jnp.floor(tf / s)
    return (q - c * jnp.floor(q / c)).astype(jnp.int32)


def _fired_packed(digits: jnp.ndarray, tab: jnp.ndarray) -> jnp.ndarray:
    """Fired-rule lookup ``tab[..., μ, digits[..., t, μ]]`` as (..., T, m).

    ``R`` is small by construction, so an unrolled select beats a dynamic
    per-element gather (~8x on CPU); the gather fallback covers rule-heavy
    systems.  Digits are always < choices ≤ R, and slot 0 of an empty
    neuron is 0 (no rule fires).
    """
    R = tab.shape[-1]
    if R <= 8:
        packed_f = jnp.zeros(digits.shape, jnp.int32)
        for d in range(R):
            packed_f = jnp.where(
                digits == d, tab[..., None, :, d], packed_f)
        return packed_f
    batch = digits.shape[:-2]
    T, m = digits.shape[-2:]
    flat_b = int(np.prod(batch)) if batch else 1
    offs = (jnp.arange(m, dtype=jnp.int32) * R).reshape(1, 1, m)
    flat = (digits.reshape(flat_b, T, m) + offs).reshape(flat_b, T * m)
    out = jnp.take_along_axis(tab.reshape(flat_b, m * R), flat, axis=-1)
    return out.reshape(*batch, T, m)


def sparse_next_configs(
    config: jnp.ndarray, comp: CompiledSparseSNP, max_branches: int
) -> StepOut:
    """One synchronous SNP step on the sparse encoding.

    Produces identical *valid* entries to :func:`next_configs` without ever
    materializing the ``(..., T, n)`` one-hot spiking tensor or any
    ``O(n·m)`` matrix:

    1. decode the mixed-radix digit per (branch, neuron)     — (..., T, m);
    2. one gather into the packed per-config rule table      -> the fired
       rule's (produce, consume) per neuron;
    3. contract over the ELL in-adjacency: a fired rule's row of ``M_Π`` is
       ``-consume`` at its owner plus ``produce`` on the owner's
       out-neighbors, so ``ΔC[j] = Σ_{i ∈ in(j)} produce_fired[i] -
       consume_fired[j]`` — a ``K_in``-wide gather/segment-sum;
    4. the environment emission is the fired produce at the output neuron.

    All arithmetic is int32 (exact); agreement with the dense f32 matmul
    holds for spike counts < 2^24 (DESIGN.md §2).
    """
    m = config.shape[-1]
    batch = config.shape[:-1]
    cfg = config.reshape(-1, m)
    B = cfg.shape[0]
    T = max_branches

    info = sparse_branch_info(cfg, comp)
    tab = packed_rule_table(info, comp)                      # (B, m, R)

    t = jnp.arange(T, dtype=jnp.int32)
    digits = _decode_digits(t, info)                         # (B, T, m)
    packed_f = _fired_packed(digits, tab)                    # (B, T, m)
    prod_f = packed_f & 0xFFFF
    cons_f = packed_f >> 16

    prod_pad = jnp.concatenate(
        [prod_f, jnp.zeros((B, T, 1), jnp.int32)], axis=-1)  # (B, T, m+1)
    delta = -cons_f
    for kk in range(comp.in_idx.shape[1]):  # static K_in, unrolled
        delta = delta + jnp.take(prod_pad, comp.in_idx[:, kk], axis=-1)
    if comp.coo_src.shape[0]:  # hybrid encoding: COO tail via segment-sum
        # Tail synapses of hub neurons (in-degree past the plan's hub
        # threshold, DESIGN.md §3): gather the fired produce at each tail
        # source, segment-sum into the target neurons.  int32, exact.
        contrib = jnp.take(prod_pad, comp.coo_src, axis=-1)  # (B, T, Ec)
        tail = jax.ops.segment_sum(
            jnp.moveaxis(contrib, -1, 0), comp.coo_dst, num_segments=m)
        delta = delta + jnp.moveaxis(tail, 0, -1)

    out = cfg[:, None, :] + delta
    valid = (t[None, :].astype(jnp.float32) < info.psi[:, None]) \
        & info.alive[:, None]
    overflow = info.psi > float(T)
    emissions = jnp.take(prod_pad, comp.out_neuron, axis=-1)
    return StepOut(
        configs=out.reshape(*batch, T, m),
        valid=valid.reshape(*batch, T),
        emissions=emissions.reshape(*batch, T),
        overflow=overflow.reshape(batch),
        spiking=None,
    )


# ---------------------------------------------------------------------------
# Delayed semantics (SystemPlan(semantics="delays"), DESIGN.md §2 "Delayed
# semantics"): rules carry a firing delay d (arXiv 1212.2529 / 2211.15156).
# A configuration row widens to 3m — [spikes | countdown | pending]:
#
#   countdown[j] > 0  — neuron j is *closed*: its rules are inapplicable
#                       and incoming spikes are lost;
#   countdown[j] == 1 — j reopens THIS transition: pending[j] (the produce
#                       of the delayed rule it fired d steps ago) lands on
#                       its out-neighbors (and the environment, if j is the
#                       output neuron) at the end of the step;
#   firing a rule with d > 0 consumes immediately, sets countdown := d and
#   pending := produce; firing with d == 0 emits immediately (classic).
#
# Reception gate: neuron j receives incoming spikes iff its *post-update*
# countdown is 0 — equivalently iff it neither stays closed (cd > 1) nor
# just fired a delayed rule.  All-zero delays collapse every branch of this
# transition onto the paper's ``C' = C + S·M`` exactly.
# ---------------------------------------------------------------------------


def split_state(config: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray,
                                              jnp.ndarray]:
    """Split a delayed-state row (..., 3m) into (spikes, countdown,
    pending), each (..., m)."""
    m = config.shape[-1] // 3
    return config[..., :m], config[..., m:2 * m], config[..., 2 * m:]


def _delayed_alive(info: BranchInfo, cd: jnp.ndarray) -> BranchInfo:
    """Closed neurons keep the system live: a config with open countdowns
    must still take its (deterministic, Ψ=1) decrement step even when no
    rule is applicable, or pending spikes would never land."""
    return info._replace(alive=info.alive | jnp.any(cd > 0, axis=-1))


def delayed_branch_info(config: jnp.ndarray, comp: CompiledSNP) -> BranchInfo:
    """:func:`branch_info` under the delayed semantics: applicability is
    additionally masked by the owning neuron being open, and liveness
    extends to configs with running countdowns."""
    spikes, cd, _ = split_state(config)
    open_at_owner = jnp.take(cd, comp.rule_neuron, axis=-1) == 0
    app = applicability(spikes, comp) & open_at_owner
    return _delayed_alive(_branch_info_from_app(app, comp), cd)


def sparse_delayed_branch_info(config: jnp.ndarray,
                               comp: CompiledSparseSNP) -> BranchInfo:
    """:func:`sparse_branch_info` under the delayed semantics."""
    spikes, cd, _ = split_state(config)
    open_at_owner = jnp.take(cd, comp.rule_neuron, axis=-1) == 0
    app = applicability(spikes, comp) & open_at_owner
    return _delayed_alive(_sparse_info_from_app(app, comp), cd)


def delayed_weight_matrix(comp: CompiledSNP) -> jnp.ndarray:
    """Stacked per-rule weight matrix ``W`` (n, 4m) for the dense delayed
    step: one ``S·W`` contraction yields, per (branch, neuron), the fired
    rule's ``[consume | produce·(d=0) | d | produce·(d>0)]`` — replacing
    ``S·M`` so the dense Pallas kernel's delay stage stays a single
    accumulated matmul (kernels/snp_step/kernel.py)."""
    oh = comp.neuron_onehot.astype(jnp.float32)              # (n, m)
    d = comp.delay.astype(jnp.float32)[:, None]
    p = comp.produce.astype(jnp.float32)[:, None]
    c = comp.consume.astype(jnp.float32)[:, None]
    nodelay = (comp.delay == 0).astype(jnp.float32)[:, None]
    return jnp.concatenate(
        [oh * c, oh * (p * nodelay), oh * d, oh * (p * (1.0 - nodelay))],
        axis=-1)


def delayed_packed_actions(comp: CompiledSparseSNP
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-rule int32 payloads for the sparse delayed step's two rank
    tables (:func:`packed_rule_table`):

    * ``packed_e`` = ``produce·(d=0) | consume << 16`` — the *emit-now*
      table the core gather/segment-sum contraction consumes (a delayed
      rule's produce is withheld from the wire);
    * ``packed_d`` = ``produce | d << 16`` where ``d > 0``, else 0 — the
      delayed-action table (nonzero iff the fired rule has a delay, since
      ``d >= 1`` sets bit 16+); bounds guaranteed by ``Rule`` validation
      (``produce < 2^16`` checked at compile, ``d < 2^15``).
    """
    nodelay = comp.delay == 0
    packed_e = jnp.where(nodelay, comp.produce, 0) | (comp.consume << 16)
    packed_d = jnp.where(nodelay, 0, comp.produce | (comp.delay << 16))
    return packed_e, packed_d


def delayed_next_configs(
    config: jnp.ndarray, comp: CompiledSNP, max_branches: int
) -> StepOut:
    """One synchronous *delayed* SNP step, dense encoding: every successor
    (..., T, 3m) of every state row (..., 3m).

    The fired-rule attributes come from one stacked f32 contraction
    ``S·W`` (:func:`delayed_weight_matrix`, exact below 2^24); the
    reopen-pending fanout and the reception-gated incoming ride the 0/1
    synapse ``comp.adjacency``, which ``M``'s per-rule rows cannot carry.
    """
    spikes, cd, pd = split_state(config)
    m = spikes.shape[-1]
    info = delayed_branch_info(config, comp)
    S, valid, overflow = _decode_spiking(info, comp, max_branches)

    acc = jnp.einsum("...tn,nk->...tk", S.astype(jnp.float32),
                     delayed_weight_matrix(comp)).astype(jnp.int32)
    cons_f = acc[..., :m]
    emit_fired = acc[..., m:2 * m]
    d_f = acc[..., 2 * m:3 * m]
    prod_pend = acc[..., 3 * m:]

    reopen = (cd == 1)[..., None, :]                    # (..., 1, m)
    emit = emit_fired + jnp.where(reopen, pd[..., None, :], 0)
    incoming = jnp.einsum(
        "...ti,ij->...tj", emit.astype(jnp.float32),
        comp.adjacency.astype(jnp.float32)).astype(jnp.int32)

    fired_del = d_f > 0
    cd_next = jnp.where(fired_del, d_f,
                        jnp.maximum(cd - 1, 0)[..., None, :])
    gate = cd_next == 0
    spikes_next = spikes[..., None, :] - cons_f \
        + jnp.where(gate, incoming, 0)
    pd_next = jnp.where(fired_del, prod_pend,
                        jnp.where(reopen, 0, pd[..., None, :]))

    emit_pad = jnp.concatenate(
        [emit, jnp.zeros(emit.shape[:-1] + (1,), jnp.int32)], axis=-1)
    emissions = jnp.take(emit_pad, comp.out_neuron, axis=-1)
    out = jnp.concatenate([spikes_next, cd_next, pd_next], axis=-1)
    return StepOut(configs=out, valid=valid, emissions=emissions,
                   overflow=overflow, spiking=S)


def sparse_delayed_next_configs(
    config: jnp.ndarray, comp: CompiledSparseSNP, max_branches: int
) -> StepOut:
    """One synchronous *delayed* SNP step on the sparse encoding —
    bit-identical valid entries to :func:`delayed_next_configs`.

    Identical shape to :func:`sparse_next_configs` with two twists: the
    vector riding the ELL/COO in-adjacency is the *emit-now* vector
    (fired d=0 produce + reopening neurons' pending) instead of the raw
    fired produce, and a second rank table decodes the fired delayed
    action (``produce | d << 16``) to drive countdown/pending updates and
    the receiver gate.
    """
    width = config.shape[-1]
    batch = config.shape[:-1]
    cfg = config.reshape(-1, width)
    spikes, cd, pd = split_state(cfg)
    m = spikes.shape[-1]
    B = cfg.shape[0]
    T = max_branches

    info = sparse_delayed_branch_info(cfg, comp)
    packed_e, packed_d = delayed_packed_actions(comp)
    etab = packed_rule_table(info, comp, packed_e)           # (B, m, R)
    dtab = packed_rule_table(info, comp, packed_d)

    t = jnp.arange(T, dtype=jnp.int32)
    digits = _decode_digits(t, info)                         # (B, T, m)
    pe = _fired_packed(digits, etab)
    prod_now = pe & 0xFFFF
    cons_f = pe >> 16
    pdl = _fired_packed(digits, dtab)
    fired_del = pdl != 0
    prod_pend = pdl & 0xFFFF
    d_f = pdl >> 16

    reopen = (cd == 1)[:, None, :]
    emit = prod_now + jnp.where(reopen, pd[:, None, :], 0)   # (B, T, m)
    emit_pad = jnp.concatenate(
        [emit, jnp.zeros((B, T, 1), jnp.int32)], axis=-1)
    incoming = jnp.zeros((B, T, m), jnp.int32)
    for kk in range(comp.in_idx.shape[1]):  # static K_in, unrolled
        incoming = incoming + jnp.take(emit_pad, comp.in_idx[:, kk],
                                       axis=-1)
    if comp.coo_src.shape[0]:  # hybrid encoding: COO tail via segment-sum
        contrib = jnp.take(emit_pad, comp.coo_src, axis=-1)  # (B, T, Ec)
        tail = jax.ops.segment_sum(
            jnp.moveaxis(contrib, -1, 0), comp.coo_dst, num_segments=m)
        incoming = incoming + jnp.moveaxis(tail, 0, -1)

    cd_next = jnp.where(fired_del, d_f,
                        jnp.maximum(cd - 1, 0)[:, None, :])
    gate = cd_next == 0
    spikes_next = spikes[:, None, :] - cons_f \
        + jnp.where(gate, incoming, 0)
    pd_next = jnp.where(fired_del, prod_pend,
                        jnp.where(reopen, 0, pd[:, None, :]))

    out = jnp.concatenate([spikes_next, cd_next, pd_next], axis=-1)
    valid = (t[None, :].astype(jnp.float32) < info.psi[:, None]) \
        & info.alive[:, None]
    overflow = info.psi > float(T)
    emissions = jnp.take(emit_pad, comp.out_neuron, axis=-1)
    return StepOut(
        configs=out.reshape(*batch, T, width),
        valid=valid.reshape(*batch, T),
        emissions=emissions.reshape(*batch, T),
        overflow=overflow.reshape(batch),
        spiking=None,
    )
