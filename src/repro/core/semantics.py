"""Pure-jnp reference semantics for batched SNP simulation.

This is the mathematical core of the paper, vectorized over a *frontier*
of ``B`` configurations at once:

* applicability mask over rules            (paper Alg. 2, step II-1)
* mixed-radix rank-decode of every valid
  spiking vector — replaces the paper's
  host-side string enumeration             (paper Alg. 2, steps II-2/II-3)
* the affine transition ``C' = C + S·M``   (paper eq. 2)

Everything here is shape-static and jit/vmap/shard_map friendly.  The fused
Pallas TPU kernel (``repro.kernels.snp_step``) implements the same math with
explicit VMEM tiling; this module doubles as its oracle (``ref.py``).

Enumeration order.  Neuron 0 is the most-significant mixed-radix digit:
branch index ``t ∈ [0, Ψ)`` decodes to ``digit_i = (t // stride_i) % k_i``
with ``stride_i = Π_{j>i} k_j``, where ``k_i = max(1, #applicable rules in
neuron i)``.  Within a neuron, digit ``d`` selects the ``d``-th applicable
rule in the total order.  This enumerates exactly the Ψ valid spiking
vectors of Alg. 2 — by construction, no generate-and-filter.

Overflow discipline.  Ψ can be astronomically large; all radix products are
computed in float32, which saturates monotonically (exact for products below
2^24, +inf beyond) — see DESIGN.md §2.  Whenever ``Ψ > max_branches`` the
config is flagged in ``branch_overflow`` and only the first ``max_branches``
branches (a valid, deterministic subset) are produced.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .matrix import CompiledSNP

__all__ = [
    "applicability",
    "branch_info",
    "spiking_vectors",
    "next_configs",
    "StepOut",
]


def applicability(config: jnp.ndarray, comp: CompiledSNP) -> jnp.ndarray:
    """Boolean mask (..., n): which rules may fire at ``config`` (..., m).

    A rule with regex ``{b + t·p}`` is applicable at ``s`` spikes iff

    * exact mode:    ``s >= b`` and (``p == 0`` ? ``s == b``
                     : ``(s - b) % p == 0``)
    * covering mode: ``s >= b``  (the paper's (b-3) ``>=`` threshold;
                     with ``p > 0`` membership is against ``{b+t·p}``'s
                     downward closure, i.e. still just ``s >= b``)

    and always ``s >= consume``.
    """
    s = jnp.take(config, comp.rule_neuron, axis=-1)  # (..., n) spikes at owner
    ge_base = s >= comp.regex_base
    diff = s - comp.regex_base
    on_progression = jnp.where(
        comp.regex_period > 0,
        (diff % jnp.maximum(comp.regex_period, 1)) == 0,
        s == comp.regex_base,
    )
    member = jnp.where(comp.covering, ge_base, ge_base & on_progression)
    return member & (s >= comp.consume)


class BranchInfo(NamedTuple):
    app: jnp.ndarray        # (..., n) bool
    rank: jnp.ndarray       # (..., n) int32 — index among applicable in neuron
    choices: jnp.ndarray    # (..., m) int32 — max(1, #applicable)
    stride: jnp.ndarray     # (..., m) float32 — Π_{j>i} choices_j (exact < 2^24)
    psi: jnp.ndarray        # (...,)  float32 — Ψ (saturating)
    alive: jnp.ndarray      # (...,)  bool — any rule applicable at all


def branch_info(config: jnp.ndarray, comp: CompiledSNP) -> BranchInfo:
    app = applicability(config, comp)
    app_i = app.astype(jnp.int32)
    onehot = comp.neuron_onehot.astype(jnp.int32)  # (n, m)

    # #applicable per neuron, and per-rule rank among the applicable rules of
    # its own neuron.  Rules are neuron-sorted, so an inclusive cumsum minus
    # the neuron's exclusive prefix gives the within-neuron rank.
    k = app_i @ onehot                       # (..., m)
    incl = jnp.cumsum(app_i, axis=-1)        # (..., n)
    # exclusive prefix at each rule's neuron start: total applicable in all
    # earlier neurons = sum over neurons j < neuron(i) of k_j.
    k_prefix = jnp.cumsum(k, axis=-1) - k    # (..., m) exclusive over neurons
    start = jnp.take_along_axis(
        k_prefix,
        jnp.broadcast_to(comp.rule_neuron, app.shape).astype(jnp.int32),
        axis=-1,
    )
    rank = incl - start - 1                  # valid where app

    choices = jnp.maximum(k, 1)
    cf = choices.astype(jnp.float32)
    # stride_i = Π_{j > i} choices_j ; suffix products via reversed cumprod.
    suffix = jnp.cumprod(cf[..., ::-1], axis=-1)[..., ::-1]  # Π_{j >= i}
    psi = suffix[..., 0]
    stride = jnp.concatenate(
        [suffix[..., 1:], jnp.ones_like(cf[..., :1])], axis=-1
    )
    alive = jnp.any(app, axis=-1)
    return BranchInfo(app=app, rank=rank, choices=choices, stride=stride,
                      psi=psi, alive=alive)


def spiking_vectors(
    config: jnp.ndarray, comp: CompiledSNP, max_branches: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All valid spiking vectors at ``config``.

    Returns ``(S, valid, overflow)`` with ``S``: (..., T, n) int32 in
    **neuron-sorted rule order** (use ``comp.rule_order`` to map back to the
    paper's total order), ``valid``: (..., T) bool, ``overflow``: (...,) bool.
    Dead configs (no applicable rule) produce no valid branches.
    """
    info = branch_info(config, comp)
    T = max_branches
    t = jnp.arange(T, dtype=jnp.int32)

    # Mixed-radix decode directly in *rule space*: gather each rule's
    # neuron-stride/choice first ((..., n) tensors), then decode per branch.
    # This skips the (..., T, m) digit tensor and the (..., T, n) gather —
    # ~25% less HBM traffic on wide systems (EXPERIMENTS.md §Perf cell C).
    # Strides are exact in float32 whenever Ψ <= T (see module docstring);
    # clamp before casting so saturated strides stay valid int32 (yielding
    # digit 0: a legal choice).
    stride_i = jnp.minimum(info.stride, 2.0 ** 30).astype(jnp.int32)
    rule_idx = comp.rule_neuron.astype(jnp.int32)
    stride_r = jnp.take(stride_i, rule_idx, axis=-1)      # (..., n)
    choices_r = jnp.take(info.choices, rule_idx, axis=-1)  # (..., n)
    digits_r = (
        t[:, None] // stride_r[..., None, :]
    ) % choices_r[..., None, :]                            # (..., T, n)
    S = (
        info.app[..., None, :]
        & (digits_r == info.rank[..., None, :])
    ).astype(jnp.int32)

    valid = (t.astype(jnp.float32) < info.psi[..., None]) & info.alive[..., None]
    overflow = info.psi > float(T)
    return S, valid, overflow


class StepOut(NamedTuple):
    configs: jnp.ndarray    # (..., T, m) int32 — successor configurations
    valid: jnp.ndarray      # (..., T) bool
    emissions: jnp.ndarray  # (..., T) int32 — spikes sent to the environment
    overflow: jnp.ndarray   # (...,) bool — Ψ exceeded max_branches
    spiking: jnp.ndarray    # (..., T, n) int32 — the spiking vectors used


def next_configs(
    config: jnp.ndarray, comp: CompiledSNP, max_branches: int
) -> StepOut:
    """One synchronous SNP step: every successor of every config.

    ``C' = C + S · M_Π`` (paper eq. 2), batched over leading dims and over
    all ``T = max_branches`` candidate branches.
    """
    S, valid, overflow = spiking_vectors(config, comp, max_branches)
    # f32 matmul is exact for |values| < 2^24 and maps onto the MXU on TPU;
    # spike counts beyond 2^24 are out of scope (would overflow int32 fast).
    delta = jnp.einsum(
        "...tn,nm->...tm", S.astype(jnp.float32), comp.M.astype(jnp.float32)
    ).astype(jnp.int32)
    out = config[..., None, :] + delta
    emissions = jnp.einsum(
        "...tn,n->...t", S.astype(jnp.float32),
        comp.env_produce.astype(jnp.float32),
    ).astype(jnp.int32)
    return StepOut(configs=out, valid=valid, emissions=emissions,
                   overflow=overflow, spiking=S)
