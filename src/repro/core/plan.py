"""Partition/encoding planning layer: one `SystemPlan` in front of compile.

The paper's matrix representation makes the SNP transition device-friendly,
but a monolithic per-device encoding stops at ~10^4 neurons.  Everything a
compiler must decide *about storage layout* — and nothing about semantics —
lives here:

* **encoding per neuron block** — ``"dense"`` (the paper's ``M_Π``),
  ``"ell"`` (PR 2's ELL/segment layout), or ``"hybrid"``: ELL capped at a
  hub threshold with the tail synapses of heavy neurons spilled into a COO
  segment combined by segment-sum.  Hybrid is the heavy-tail answer
  (power-law graphs without ``max_in``): pure ELL pads *every* neuron's
  in-adjacency row to the top hub's in-degree, hybrid pads only to the
  threshold (DESIGN.md §3).
* **neuron-axis partition** — ``num_shards > 1`` lowers to a
  :class:`ShardedCompiled`: per-shard encodings (stacked so shard ``d``'s
  slice rides a ``shard_map`` device axis) plus the halo/exchange metadata
  saying which remote neuron segments each shard's rules read.  Consumed by
  :func:`repro.core.distributed.explore_distributed` (DESIGN.md §2).

Backends accept a plan in ``compile(system, plan=...)``
(:mod:`repro.core.backend`); the default plan (``SystemPlan()``) reproduces
each backend's historical encoding bit-for-bit, so every existing workload
is unchanged until a plan asks for more.

Decision rules (``SystemPlan.for_system``): let ``mean`` be the mean
in-degree and ``Kin`` the max.  The auto hub threshold is
``H = max(4, 4·ceil(mean))`` — wide enough that regular graphs
(ring lattice, torus, Erdős–Rényi at benchmark densities) keep a zero COO
tail, tight enough that a power-law hub spills.  Hybrid is chosen iff
``Kin > 2·H`` (the padding saved is at least half the ELL array);
otherwise plain ELL.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .system import SNPSystem

__all__ = [
    "KernelConfig",
    "SystemPlan",
    "ShardArrays",
    "DenseShardArrays",
    "ShardedCompiled",
    "auto_hub_threshold",
    "compile_sharded",
    "is_sharded",
    "lower_shard_dense",
    "partition_neurons",
    "partition_stats",
]

_ENCODINGS = ("auto", "dense", "ell", "hybrid")
_MODES = ("auto", "measure", "static")
_SEMANTICS = ("no_delays", "delays")
_PARTITIONS = ("contiguous", "degree")

# Dummy padding rules (sharded lowering) use this regex base: applicability
# requires spikes == 2^24, which the engine's spike-count contract
# (DESIGN.md §2, counts < 2^24) makes unreachable.
_NEVER_BASE = 1 << 24


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Grid/block shape for the fused Pallas lowerings, lifted out of the
    kernel wrappers so a plan can carry it (DESIGN.md §3 "Planner &
    autotuner").

    * ``block_b`` / ``block_t`` — batch / branch tile; both kernels grid
      over ``(B/bb, T/bt)``.
    * ``block_n`` — rule-axis tile of the **dense** kernel only (the
      sparse kernel keeps the whole neuron axis resident per block);
      setting it for a sparse lowering is a lower-time error.

    ``None`` fields mean "keep that axis's wrapper default".  Frozen and
    hashable, so a config rides ``jit(static_argnames=...)`` and keys the
    per-backend compile caches (two block shapes never collide into one
    cached executable)."""

    block_b: Optional[int] = None
    block_t: Optional[int] = None
    block_n: Optional[int] = None

    def __post_init__(self) -> None:
        for field in ("block_b", "block_t", "block_n"):
            v = getattr(self, field)
            if v is None:
                continue
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"KernelConfig.{field} must be a positive int or "
                    f"None, got {v!r}")

    @staticmethod
    def dense_default() -> "KernelConfig":
        """The dense wrapper defaults (``ops.snp_step``)."""
        return KernelConfig(block_b=8, block_t=128, block_n=512)

    @staticmethod
    def sparse_default() -> "KernelConfig":
        """The sparse wrapper defaults (``sparse_ops.snp_step_sparse``);
        no ``block_n`` — the neuron axis is never tiled."""
        return KernelConfig(block_b=8, block_t=32)

    def merged(self, *, block_b: Optional[int] = None,
               block_t: Optional[int] = None,
               block_n: Optional[int] = None) -> "KernelConfig":
        """This config with explicit per-axis overrides folded in
        (explicit kwarg > this config's field)."""
        return KernelConfig(
            block_b=self.block_b if block_b is None else block_b,
            block_t=self.block_t if block_t is None else block_t,
            block_n=self.block_n if block_n is None else block_n,
        )


@dataclasses.dataclass(frozen=True)
class SystemPlan:
    """How to lay an SNP system out on device(s).

    * ``encoding`` — ``"auto"`` (the backend's native layout: dense for
      ``ref``/``pallas``, ELL for the sparse pair), ``"dense"``, ``"ell"``,
      or ``"hybrid"`` (ELL capped at ``hub_threshold`` + COO tail).
    * ``hub_threshold`` — in-degree cap for the hybrid ELL part; ``None``
      lets :func:`auto_hub_threshold` pick from the degree histogram.
    * ``num_shards`` — neuron-axis partition count; ``> 1`` lowers through
      :func:`compile_sharded` and is only consumed by
      ``explore_distributed`` (one shard per device).
    * ``mode`` — how :func:`for_system` (and the entry points that call it
      when the caller names no backend) decide: ``"auto"`` consults the
      autotune cache then the analytic cost model, ``"measure"`` runs the
      autotuner inline, ``"static"`` keeps the degree heuristic
      (:mod:`repro.core.autotune`, DESIGN.md §3 "Planner & autotuner").
    * ``backend`` — step-backend registry name the planner picked (or the
      caller pinned); ``None`` leaves the choice to the call site.
    * ``kernel`` — optional :class:`KernelConfig` block shape for Pallas
      backends; validated at lower time (``resolve_kernel``) against the
      backend it lands on.
    * ``semantics`` — transition-semantics tier: ``"no_delays"`` (the
      paper's delay-free systems, the default, bit-identical to the
      historical behavior) or ``"delays"`` (per-rule firing delays with
      neuron open/closed state; configurations widen to ``3m`` —
      DESIGN.md "Delayed semantics").  A backend that cannot realize an
      encoding under the requested tier raises at compile time
      (``supported_encodings(semantics=...)``), never downgrades.
    * ``partition`` — how neurons map to shards when ``num_shards > 1``:
      ``"contiguous"`` (the historical ``mloc``-sized slices, bit-identical
      layout) or ``"degree"`` (hub-aware greedy bin-packing: neurons are
      placed heaviest-degree-first onto the least-loaded shard, so the
      hubs of a power-law graph spread across devices instead of piling
      onto whichever slice they fall in — :func:`partition_neurons`).
      Per-shard occupancy lands on ``ShardedCompiled.occupancy`` so the
      planner can report imbalance (:func:`partition_stats`).

    Frozen and hashable, so a plan can ride through
    ``jit(static_argnames=...)`` with the backend.
    """

    encoding: str = "auto"
    hub_threshold: Optional[int] = None
    num_shards: int = 1
    mode: str = "auto"
    backend: Optional[str] = None
    kernel: Optional[KernelConfig] = None
    semantics: str = "no_delays"
    partition: str = "contiguous"

    def __post_init__(self) -> None:
        if self.encoding not in _ENCODINGS:
            raise ValueError(
                f"unknown encoding {self.encoding!r}; one of {_ENCODINGS}")
        if self.semantics not in _SEMANTICS:
            raise ValueError(
                f"unknown semantics {self.semantics!r}; one of {_SEMANTICS}")
        if self.hub_threshold is not None and self.hub_threshold < 1:
            raise ValueError(
                f"hub_threshold must be >= 1, got {self.hub_threshold}")
        if self.num_shards < 1:
            raise ValueError(
                f"num_shards must be >= 1, got {self.num_shards}")
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; one of {_MODES}")
        if self.kernel is not None and not isinstance(self.kernel,
                                                      KernelConfig):
            raise ValueError(
                f"plan kernel must be a KernelConfig or None, "
                f"got {type(self.kernel).__name__}")
        if self.partition not in _PARTITIONS:
            raise ValueError(
                f"unknown partition {self.partition!r}; one of {_PARTITIONS}")

    @staticmethod
    def default() -> "SystemPlan":
        """The identity plan: every backend keeps its historical encoding."""
        return SystemPlan()

    @staticmethod
    def for_system(system: SNPSystem, *,
                   num_shards: int = 1,
                   workload: Optional[Tuple[int, int]] = None,
                   mode: str = "static",
                   semantics: str = "no_delays") -> "SystemPlan":
        """Concrete plan for ``system``.

        ``mode="static"`` (the default) keeps the degree heuristic
        (module docstring rules): hybrid iff the max in-degree is
        heavy-tailed relative to the mean, else plain ELL.  With
        ``num_shards > 1`` the encoding stays ELL regardless — the
        per-shard lowering is ELL-only (:func:`compile_sharded` refuses
        the hybrid combination).

        ``mode="auto"`` consults the autotune cache (seeded from the
        committed bench baseline) and falls back to the analytic cost
        model; ``mode="measure"`` times candidate configurations inline
        and persists the winner (:mod:`repro.core.autotune`).  Both fall
        through to the static heuristic when the planner has nothing to
        say.  ``workload=(B, T)`` is the batch/branch shape the plan will
        serve — the dense/sparse crossover depends on it, not just on the
        degree histogram."""
        if mode not in _MODES:
            raise ValueError(f"unknown mode {mode!r}; one of {_MODES}")
        if semantics not in _SEMANTICS:
            raise ValueError(
                f"unknown semantics {semantics!r}; one of {_SEMANTICS}")
        if semantics == "delays" and num_shards > 1:
            raise ValueError(
                "no backend shards semantics='delays' yet; use "
                "num_shards=1 for delayed systems")
        if mode != "static":
            from . import autotune  # lazy: autotune imports backend
            plan = autotune.plan_for(system, num_shards=num_shards,
                                     workload=workload,
                                     measure=(mode == "measure"),
                                     semantics=semantics)
            if plan is not None:
                return plan
        in_deg = _in_degrees(system)
        h = auto_hub_threshold(in_deg)
        kin = int(in_deg.max()) if in_deg.size else 0
        if num_shards == 1 and kin > 2 * h:
            return SystemPlan(encoding="hybrid", hub_threshold=h,
                              mode=mode, semantics=semantics)
        # Heavy-tailed graph over >1 shard: spread the hubs (the same
        # degree test that triggers hybrid single-device).
        part = "degree" if (num_shards > 1 and kin > 2 * h) else "contiguous"
        return SystemPlan(encoding="ell", num_shards=num_shards, mode=mode,
                          semantics=semantics, partition=part)

    def resolved_hub_threshold(self, system: SNPSystem) -> Optional[int]:
        """The hub threshold ``compile_system_sparse`` should cap ELL rows
        at: ``None`` unless this plan asks for the hybrid encoding."""
        if self.encoding != "hybrid":
            return None
        if self.hub_threshold is not None:
            return self.hub_threshold
        return auto_hub_threshold(_in_degrees(system))


def _in_degrees(system: SNPSystem) -> np.ndarray:
    syn = np.asarray(system.synapses, np.int64).reshape(-1, 2)
    return np.bincount(syn[:, 1], minlength=system.num_neurons) \
        if syn.size else np.zeros((system.num_neurons,), np.int64)


def auto_hub_threshold(in_deg: np.ndarray) -> int:
    """``max(4, 4·ceil(mean nonzero in-degree))`` — see module docstring."""
    in_deg = np.asarray(in_deg)
    nz = in_deg[in_deg > 0]
    mean = float(nz.mean()) if nz.size else 0.0
    return max(4, 4 * math.ceil(mean))


# ---------------------------------------------------------------------------
# Neuron-axis sharded lowering
# ---------------------------------------------------------------------------


class ShardArrays(NamedTuple):
    """Stacked per-shard arrays: leading axis ``S`` = shard id, sharded
    ``P(axis)`` into a ``shard_map`` so device ``d`` sees shard ``d``'s
    slice.  ``rule_slots`` is the one replicated leaf (it carries ``R`` in
    its shape for every shard alike).

    Shapes: ``S`` shards, ``mloc = ceil(m/S)`` neurons per shard, ``nloc``
    = max rules per shard (padded with never-applicable dummies *after*
    the real, neuron-sorted prefix — the segment tables only cover the
    real prefix), ``Kin`` = max in-degree, ``Hmax`` = max halo segment
    between any shard pair.

    ``in_idx`` indexes the *extended* per-device produce buffer
    ``[local (mloc) | halo (S·Hmax) | zero (1)]``: a remote in-neighbor
    owned by shard ``o`` at halo slot ``s`` is ``mloc + o·Hmax + s``;
    padding points at the trailing zero (``mloc + S·Hmax``).
    ``send_idx[d, p]`` lists the local neuron indices shard ``d`` must
    ship to peer ``p`` (ascending, padded with ``mloc`` = a zero slot),
    so one tiled ``all_to_all`` realizes every halo.
    """

    rule_neuron: jnp.ndarray    # (S, nloc) i32 — local neuron of each rule
    consume: jnp.ndarray        # (S, nloc) i32
    produce: jnp.ndarray        # (S, nloc) i32
    regex_base: jnp.ndarray     # (S, nloc) i32
    regex_period: jnp.ndarray   # (S, nloc) i32
    covering: jnp.ndarray       # (S, nloc) bool
    seg_start: jnp.ndarray      # (S, mloc) i32
    seg_count: jnp.ndarray      # (S, mloc) i32
    rule_slots: jnp.ndarray     # (R,) i32 == arange(R)  [replicated]
    in_idx: jnp.ndarray         # (S, mloc, Kin) i32 — extended space
    send_idx: jnp.ndarray       # (S, S, Hmax) i32 — local ids, pad mloc
    out_local: jnp.ndarray      # (S,) i32 — local output neuron or mloc
    init_loc: jnp.ndarray       # (S, mloc) i32 — C_0 slices (zero padded)
    global_idx: jnp.ndarray     # (S, mloc) i32 — global neuron id per
    #   column (pads get the unused ids m..S·mloc-1); feeds zobrist
    #   positions + archive reassembly under any partition


class ShardView(NamedTuple):
    """One shard's de-stacked arrays, duck-typing the ``CompiledSparseSNP``
    fields that :func:`repro.core.semantics.applicability`,
    :func:`~repro.core.semantics.sparse_branch_info` and
    :func:`~repro.core.semantics.packed_rule_table` read — so the sharded
    device step reuses the sparse reference math verbatim on its local
    neuron slice."""

    rule_neuron: jnp.ndarray
    consume: jnp.ndarray
    produce: jnp.ndarray
    regex_base: jnp.ndarray
    regex_period: jnp.ndarray
    covering: jnp.ndarray
    seg_start: jnp.ndarray
    seg_count: jnp.ndarray
    rule_slots: jnp.ndarray

    @property
    def num_rules(self) -> int:
        return self.rule_neuron.shape[0]

    @property
    def num_neurons(self) -> int:
        return self.seg_start.shape[0]


class DenseShardArrays(NamedTuple):
    """Per-shard *dense* kernel operands (DESIGN.md §3 "Kernel lowering"),
    attached by ``PallasBackend.lower`` so the fused dense kernel can
    consume a shard: ``C' = C + halo·hadj + S·M_local``.  Stacked like
    :class:`ShardArrays` (leading axis ``S``, sharded ``P(axis)``).

    ``M_local[d]`` restricts each local rule's row of ``M_Π`` to shard
    ``d``'s columns (``-consume`` at the owner, ``produce`` on *local*
    out-neighbors; dummy padding rules are all-zero — they never fire
    anyway).  ``hadj[d][s, j] = 1`` iff halo slot ``s`` of the extended
    index space feeds local neuron ``j`` — remote produce enters as one
    extra matmul instead of a gather."""

    M_local: jnp.ndarray        # (S, nloc, mloc) i32
    onehot: jnp.ndarray         # (S, nloc, mloc) i8 — rule→local neuron
    hadj: jnp.ndarray           # (S, S·Hmax, mloc) i8


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedCompiled:
    """Neuron-axis partitioned lowering: stacked shard encodings + halo
    metadata.  Produced by :func:`compile_sharded`, consumed by
    ``explore_distributed`` (DESIGN.md §2); the static ints live outside
    the array pytree so they stay Python constants under ``jit``.
    ``dense`` is the optional dense-kernel view of the same shards
    (:class:`DenseShardArrays`), attached by ``PallasBackend.lower``."""

    arrays: ShardArrays
    plan: SystemPlan
    num_neurons: int            # true m (before padding to S·mloc)
    num_rules: int              # true n (before dummy padding)
    shard_size: int             # mloc
    num_shards: int             # S
    halo_width: int             # Hmax
    dense: Optional[DenseShardArrays] = None
    occupancy: Optional[np.ndarray] = None   # (S,) degree weight per shard

    @property
    def init_config(self) -> jnp.ndarray:
        """Full (m,) initial configuration, reassembled from the slices
        via the column→global-neuron map (identity for contiguous
        partitions, a scatter for degree-weighted ones)."""
        flat = self.arrays.init_loc.reshape(-1)
        gidx = self.arrays.global_idx.reshape(-1)
        return jnp.zeros_like(flat).at[gidx].set(flat)[: self.num_neurons]


def is_sharded(obj) -> bool:
    return isinstance(obj, ShardedCompiled)


def _degree_weights(system: SNPSystem) -> np.ndarray:
    """Per-neuron work weight: in-degree + out-degree + 1.  Degree drives
    both the gather width a neuron costs per step (in-adjacency rows) and
    the halo traffic it can induce (out-synapses crossing shards); the +1
    floors isolated neurons at one slot of work."""
    syn = np.asarray(system.synapses, np.int64).reshape(-1, 2)
    w = np.ones((system.num_neurons,), np.int64)
    if syn.size:
        w += np.bincount(syn[:, 0], minlength=system.num_neurons)
        w += np.bincount(syn[:, 1], minlength=system.num_neurons)
    return w


def partition_neurons(system: SNPSystem, num_shards: int,
                      partition: str = "contiguous"
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Neuron→shard assignment: ``(shard_of (m,), local_of (m,),
    global_idx (S, mloc), occupancy (S,))``.

    ``"contiguous"`` is the historical slicing (neuron ``j`` → shard
    ``j // mloc``).  ``"degree"`` is LPT-style greedy bin-packing under
    the hard capacity ``mloc``: neurons in descending degree-weight order
    (ties by index — deterministic) each go to the least-loaded shard
    with a free slot (ties to the lowest shard id).  On a power-law
    graph the hubs land on *different* shards, so per-shard occupancy
    (summed :func:`_degree_weights`) flattens instead of tracking
    whichever contiguous slice the hubs fell into.

    ``global_idx[d, c]`` is the global neuron a shard column holds; pad
    columns take the unused ids ``m..S·mloc-1`` so every column has a
    distinct global position (the zobrist position space stays injective,
    and pads — always zero spikes — contribute a constant to every
    hash)."""
    if partition not in _PARTITIONS:
        raise ValueError(
            f"unknown partition {partition!r}; one of {_PARTITIONS}")
    S, m = num_shards, system.num_neurons
    mloc = -(-m // S)
    w = _degree_weights(system)
    if partition == "contiguous":
        ids = np.arange(m, dtype=np.int64)
        shard_of = (ids // mloc).astype(np.int32)
        local_of = (ids % mloc).astype(np.int32)
        global_idx = np.arange(S * mloc, dtype=np.int32).reshape(S, mloc)
    else:
        shard_of = np.zeros((m,), np.int32)
        local_of = np.zeros((m,), np.int32)
        load = np.zeros((S,), np.int64)
        cnt = np.zeros((S,), np.int64)
        for j in np.argsort(-w, kind="stable"):
            free = np.flatnonzero(cnt < mloc)
            d = int(free[np.argmin(load[free])])
            shard_of[j] = d
            local_of[j] = cnt[d]
            load[d] += w[j]
            cnt[d] += 1
        global_idx = np.zeros((S, mloc), np.int32)
        global_idx[shard_of, local_of] = np.arange(m, dtype=np.int32)
        pad = m
        for d in range(S):
            for c in range(int(cnt[d]), mloc):
                global_idx[d, c] = pad
                pad += 1
    occupancy = np.zeros((S,), np.int64)
    np.add.at(occupancy, shard_of, w)
    return shard_of, local_of, global_idx, occupancy


def partition_stats(occupancy: np.ndarray) -> dict:
    """Imbalance summary of a shard assignment: max / mean per-shard
    occupancy and their ratio (1.0 = perfectly level).  The planner and
    the ``explore/partition`` bench tier report these."""
    occ = np.asarray(occupancy, np.float64)
    mean = float(occ.mean()) if occ.size else 0.0
    mx = float(occ.max()) if occ.size else 0.0
    return {"max": mx, "mean": mean,
            "imbalance": (mx / mean) if mean else 1.0}


def compile_sharded(system: SNPSystem, plan: SystemPlan) -> ShardedCompiled:
    """Lower ``system`` to ``plan.num_shards`` neuron-axis shards.

    Host-side numpy, same vectorized-adjacency discipline as the other
    compilers (the only Python loops are over ``S`` and ``S²`` shard
    pairs).  Every shard gets identical array *shapes* (rules padded with
    never-applicable dummies, halos padded to the max pair width) so the
    stacked arrays ride one ``shard_map`` program.
    """
    # Local import: matrix imports stay plan-free (plan -> matrix only).
    from .matrix import _lower, _ragged_arange

    if plan.semantics == "delays":
        # The halo exchange has no notion of countdown/pending state yet;
        # raise here too so explore_distributed (which reaches this
        # compiler directly) cannot silently run delays sharded.
        raise ValueError(
            "neuron-axis sharding does not support semantics='delays' "
            "(the halo exchange carries spike counts only); run delayed "
            "systems single-device")
    if plan.encoding == "hybrid":
        # The per-shard encodings are ELL-only (hub tails widen the halo
        # instead of spilling to COO), and the compile contract
        # (backend.py) forbids silently downgrading a requested encoding
        # — refuse instead.
        raise ValueError(
            "neuron-axis sharding does not support the hybrid ELL+COO "
            "encoding (the sharded step gathers over per-shard ELL "
            "rows only); use encoding='ell' with num_shards > 1")
    if plan.encoding not in ("auto", "ell"):
        # Same contract when explore_distributed reaches here directly,
        # bypassing the backend's _require_encoding check.
        raise ValueError(
            f"neuron-axis sharding lowers to per-shard ELL encodings; "
            f"plan encoding {plan.encoding!r} cannot be realized "
            "(supported: 'auto', 'ell')")
    S = plan.num_shards
    m = system.num_neurons
    low = _lower(system)
    n = low.neuron.shape[0]
    mloc = -(-m // S)
    # Neuron→shard assignment: everything below speaks shard_of/local_of,
    # so contiguous slices and degree-weighted packing share one lowering
    # (contiguous reduces to the historical // mloc arithmetic exactly).
    shard_of, local_of, global_idx, occupancy = partition_neurons(
        system, S, plan.partition)

    # -- rules, re-indexed to local neurons, padded with dummies ----------
    # Grouped by shard, sorted by *local* neuron (stable): the segment
    # tables index rules by local id, and under a degree partition local
    # order no longer matches the lowering's global-neuron sort.
    r_shard = shard_of[low.neuron]
    r_local = local_of[low.neuron]
    rorder = np.lexsort((r_local, r_shard))
    counts = np.bincount(r_shard, minlength=S)
    nloc = int(max(1, counts.max()))
    starts = np.cumsum(counts) - counts

    rn = np.full((S, nloc), mloc - 1, np.int32)
    cons = np.ones((S, nloc), np.int32)
    prod = np.zeros((S, nloc), np.int32)
    base = np.full((S, nloc), _NEVER_BASE, np.int32)
    period = np.zeros((S, nloc), np.int32)
    cov = np.zeros((S, nloc), bool)
    seg_count = np.zeros((S, mloc), np.int32)
    for d in range(S):
        k = int(counts[d])
        sl = rorder[int(starts[d]): int(starts[d]) + k]
        rn[d, :k] = r_local[sl]
        cons[d, :k] = low.consume[sl]
        prod[d, :k] = low.produce[sl]
        base[d, :k] = low.regex_base[sl]
        period[d, :k] = low.regex_period[sl]
        cov[d, :k] = low.covering[sl]
        seg_count[d] = np.bincount(rn[d, :k], minlength=mloc)
    seg_start = (np.cumsum(seg_count, axis=1) - seg_count).astype(np.int32)
    R = int(max(1, seg_count.max()))

    # -- halo metadata: which locals each shard ships to each peer --------
    src, dst = low.src.astype(np.int64), low.dst.astype(np.int64)
    ssh, dsh = shard_of[src], shard_of[dst]
    halo = {}
    hmax = 1
    for o in range(S):
        for d in range(S):
            if o == d:
                continue
            need = np.unique(src[(dsh == d) & (ssh == o)])
            if need.size:
                halo[(o, d)] = need
                hmax = max(hmax, int(need.size))
    # slot p of the (o, d) halo carries the p-th *globally-sorted* needed
    # source; its local id on shard o is local_of[need[p]] (not ascending
    # under a degree partition — the order just has to match in_idx below)
    send_idx = np.full((S, S, hmax), mloc, np.int32)
    for (o, d), need in halo.items():
        send_idx[o, d, : need.size] = local_of[need]

    # -- in-adjacency in extended [local | halo | zero] index space -------
    in_deg = np.bincount(dst, minlength=m)
    kin = int(max(1, in_deg.max() if in_deg.size else 0))
    z = mloc + S * hmax
    in_idx = np.full((S, mloc, kin), z, np.int32)
    if src.size:
        order = np.lexsort((src, dst))
        s_s, d_s = src[order], dst[order]
        slot = _ragged_arange(in_deg)
        e_dsh, e_ssh = shard_of[d_s], shard_of[s_s]
        ext = np.where(e_ssh == e_dsh, local_of[s_s], -1)
        for (o, d), need in halo.items():
            sel = (e_ssh == o) & (e_dsh == d)
            if sel.any():
                pos = np.searchsorted(need, s_s[sel])
                ext[sel] = mloc + o * hmax + pos
        in_idx[e_dsh, local_of[d_s], slot] = ext

    out_local = np.full((S,), mloc, np.int32)
    if system.output_neuron >= 0:
        out_local[shard_of[system.output_neuron]] = \
            local_of[system.output_neuron]

    init_loc = np.zeros((S, mloc), np.int32)
    init_loc[shard_of, local_of] = np.asarray(system.initial_spikes,
                                              np.int32)

    arrays = ShardArrays(
        rule_neuron=jnp.asarray(rn), consume=jnp.asarray(cons),
        produce=jnp.asarray(prod), regex_base=jnp.asarray(base),
        regex_period=jnp.asarray(period), covering=jnp.asarray(cov),
        seg_start=jnp.asarray(seg_start), seg_count=jnp.asarray(seg_count),
        rule_slots=jnp.arange(R, dtype=jnp.int32),
        in_idx=jnp.asarray(in_idx), send_idx=jnp.asarray(send_idx),
        out_local=jnp.asarray(out_local),
        init_loc=jnp.asarray(init_loc),
        global_idx=jnp.asarray(global_idx),
    )
    return ShardedCompiled(arrays=arrays, plan=plan, num_neurons=m,
                           num_rules=n, shard_size=mloc, num_shards=S,
                           halo_width=hmax, occupancy=occupancy)


def lower_shard_dense(comp: ShardedCompiled) -> ShardedCompiled:
    """Attach the dense-kernel operands (:class:`DenseShardArrays`) to a
    sharded lowering.  Host-side numpy (same contract as the compilers);
    idempotent — an already-lowered object passes through."""
    if comp.dense is not None:
        return comp
    from .matrix import _ragged_arange  # plan -> matrix only (no cycle)
    a = comp.arrays
    S, mloc, hmax = comp.num_shards, comp.shard_size, comp.halo_width
    nloc = a.rule_neuron.shape[1]
    rn = np.asarray(a.rule_neuron)
    cons = np.asarray(a.consume)
    prod = np.asarray(a.produce)
    base = np.asarray(a.regex_base)
    seg_start = np.asarray(a.seg_start)
    seg_count = np.asarray(a.seg_count)
    in_idx = np.asarray(a.in_idx)

    M = np.zeros((S, nloc, mloc), np.int32)
    onehot = np.zeros((S, nloc, mloc), np.int8)
    hadj = np.zeros((S, S * hmax, mloc), np.int8)
    for d in range(S):
        real = np.nonzero(base[d] != _NEVER_BASE)[0]
        M[d, real, rn[d, real]] = -cons[d, real]
        onehot[d, real, rn[d, real]] = 1
        # local synapses: in_idx entries below mloc are local sources; a
        # source's every rule writes its produce into the target column.
        jj, kk = np.nonzero(in_idx[d] < mloc)
        src = in_idx[d][jj, kk]
        cnt = seg_count[d, src].astype(np.int64)
        rr = np.repeat(seg_start[d, src], cnt) + _ragged_arange(cnt)
        np.add.at(M[d], (rr, np.repeat(jj, cnt)), prod[d, rr])
        # halo slots feeding local neurons (extended-space indices).
        hj, hk = np.nonzero((in_idx[d] >= mloc) &
                            (in_idx[d] < mloc + S * hmax))
        hadj[d][in_idx[d][hj, hk] - mloc, hj] = 1
    return dataclasses.replace(comp, dense=DenseShardArrays(
        M_local=jnp.asarray(M), onehot=jnp.asarray(onehot),
        hadj=jnp.asarray(hadj)))


def shard_view(arrays: ShardArrays) -> ShardView:
    """Per-device view of stacked arrays whose leading shard axis has
    already been split away by ``shard_map`` (each field is ``(1, ...)``
    except the replicated ``rule_slots``)."""
    return ShardView(
        rule_neuron=arrays.rule_neuron[0], consume=arrays.consume[0],
        produce=arrays.produce[0], regex_base=arrays.regex_base[0],
        regex_period=arrays.regex_period[0], covering=arrays.covering[0],
        seg_start=arrays.seg_start[0], seg_count=arrays.seg_count[0],
        rule_slots=arrays.rule_slots,
    )
