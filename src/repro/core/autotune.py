"""Cost-model query planner + ``(bb, bt)`` kernel autotuner.

The paper's matrix form gives four interchangeable lowerings of the same
``M_Π`` transition (dense / ELL / hybrid, each with a fused Pallas
kernel), and the committed bench baseline shows the right choice is
workload-dependent: the dense Pallas kernel loses to ``ref`` at every
measured shape while ``sparse_pallas`` wins only below a density/size
crossover — the central performance question the sparse SNP-on-GPU
follow-up work (arXiv 2408.04343) identifies for these systems.  This
module makes the choice automatic.  Decision flow (DESIGN.md §3
"Planner & autotuner")::

    workload signature (m, n, K_in, B, T)
        │
        ├─ 1. autotune cache ──  on-disk JSON of measured winners,
        │                        seeded from the committed BENCH_snp.json
        │                        so fresh checkouts and CI get sane
        │                        defaults without measuring
        ├─ 2. analytic model ──  per-backend log-log cost curves
        │                        us ≈ A·W^p over the dense work proxy
        │                        W = B·T·n·m, calibrated against the
        │                        bench baseline (interpret-mode kernels
        │                        are never extrapolated far past their
        │                        measured support)
        └─ 3. degree heuristic — ``SystemPlan.for_system(mode="static")``
                                 (the caller falls through when this
                                 module returns ``None``)

Entry points: :func:`plan_for` (what ``SystemPlan.for_system`` calls for
``mode="auto"|"measure"``), :func:`measure_best` (the inline sweep),
:func:`lookup`/:func:`store_choice` (cache), :func:`predict_us` (model
introspection, used by ``examples/explore_distributed.py --plan auto``).

The cache lives at ``$REPRO_AUTOTUNE_CACHE`` (else
``~/.cache/repro-snp/autotune.json``), keyed on the full workload
signature ``m{m}_n{n}_kin{kin}_B{B}_T{T}`` (bench-seeded entries use a
``kin*`` wildcard — the baseline rows don't record in-degree).  A
corrupt or poisoned file degrades to the analytic model with a
``UserWarning``; it never crashes a plan."""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from .plan import (KernelConfig, SystemPlan, _in_degrees,
                   auto_hub_threshold)
from .system import SNPSystem

__all__ = [
    "DEFAULT_WORKLOAD",
    "TunedChoice",
    "WorkloadSignature",
    "cache_path",
    "load_cache",
    "lookup",
    "measure_best",
    "model_choice",
    "plan_for",
    "predict_us",
    "save_cache",
    "signature_of",
    "store_choice",
]

# Workload shape assumed when the caller gives no (B, T) hint: the
# engine defaults (frontier_cap is larger, but 64×32 sits mid-sweep).
DEFAULT_WORKLOAD: Tuple[int, int] = (64, 32)

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_BASELINE_ENV = "REPRO_BENCH_BASELINE"
_CACHE_VERSION = 1

# Backends whose Pallas kernels currently run in interpret mode on CPU:
# their measured cost curves stop being trustworthy far outside the
# fitted support (interpret overhead explodes super-linearly — the
# committed baseline shows dense pallas at 6.65x ref by m=512), so the
# model never extrapolates them past _EXTRAPOLATION_GUARD × max fitted W.
_INTERPRET_KERNELS = ("pallas", "sparse_pallas")
_EXTRAPOLATION_GUARD = 4.0

# Fallback log-log fits us ≈ exp(logA + p·log W), W = B·T·n·m, computed
# from the committed BENCH_snp.json (snp_step + snp_step_large tiers).
# Used only when no baseline file is readable: {backend: (p, logA, Wmax)}.
_FALLBACK_FITS = {
    "ref": (0.5090, 1.0699, 1.718e10),
    "pallas": (0.5288, 1.2964, 2.147e9),
    "sparse": (0.4735, 1.0151, 1.374e11),
    "sparse_pallas": (0.4601, 0.7715, 1.342e8),
}

# Block shapes the committed bench sweep runs its kernel backends at
# (benchmarks/bench_snp.py BACKENDS) — seeded cache entries carry them so
# a seed-driven plan reproduces the measured configuration.
_BENCH_KERNELS = {
    "pallas": KernelConfig(block_b=8, block_t=16, block_n=128),
    "sparse_pallas": KernelConfig(block_b=8, block_t=16),
}

_ROW_SHAPE = re.compile(r"m(\d+)_n(\d+)_B(\d+)_T(\d+)$")


@dataclasses.dataclass(frozen=True)
class WorkloadSignature:
    """The ``(m, n, K_in, B, T, semantics)`` key a tuning decision is
    valid for: neurons, rules, max in-degree, frontier batch, branch cap,
    transition-semantics tier.  Delayed steps cost more than delay-free
    ones at the same shape (3m-wide state, the reopen/gate stage), so the
    two tiers never share a cache entry."""

    m: int
    n: int
    kin: int
    B: int
    T: int
    semantics: str = "no_delays"

    @property
    def work(self) -> float:
        """Dense work proxy ``W = B·T·n·m`` — what one step touches in
        the paper's ``C' = C + S·M_Π`` form (S is (B·T, n), M_Π (n, m))."""
        return float(self.B) * self.T * self.n * self.m

    def _suffix(self) -> str:
        # Suffix only under delays: every pre-existing cache/seed key
        # stays valid for the default tier.
        return "_delays" if self.semantics == "delays" else ""

    def key(self) -> str:
        return (f"m{self.m}_n{self.n}_kin{self.kin}"
                f"_B{self.B}_T{self.T}{self._suffix()}")

    def wildcard_key(self) -> str:
        """Key with the in-degree wildcarded — bench-seeded entries only
        know the ``(m, n, B, T)`` shape."""
        return f"m{self.m}_n{self.n}_kin*_B{self.B}_T{self.T}{self._suffix()}"


@dataclasses.dataclass(frozen=True)
class TunedChoice:
    """One planning decision: backend + encoding + block shape, with the
    measured/predicted step cost and where the decision came from
    (``seed`` = committed baseline, ``cache`` = a prior measure run,
    ``model`` = analytic fit, ``measure`` = timed right now)."""

    backend: str
    encoding: str = "auto"
    hub_threshold: Optional[int] = None
    block_b: Optional[int] = None
    block_t: Optional[int] = None
    block_n: Optional[int] = None
    us_per_step: Optional[float] = None
    source: str = "model"

    def kernel(self) -> Optional[KernelConfig]:
        if (self.block_b is None and self.block_t is None
                and self.block_n is None):
            return None
        return KernelConfig(block_b=self.block_b, block_t=self.block_t,
                            block_n=self.block_n)


def signature_of(system: SNPSystem, *,
                 workload: Optional[Tuple[int, int]] = None,
                 semantics: str = "no_delays") -> WorkloadSignature:
    """The workload signature of running ``system`` at ``workload=(B, T)``
    (``DEFAULT_WORKLOAD`` when the caller has no hint)."""
    B, T = workload if workload is not None else DEFAULT_WORKLOAD
    in_deg = _in_degrees(system)
    kin = int(in_deg.max()) if in_deg.size else 0
    return WorkloadSignature(m=system.num_neurons, n=system.num_rules,
                             kin=kin, B=int(B), T=int(T),
                             semantics=semantics)


# ---------------------------------------------------------------------------
# On-disk cache
# ---------------------------------------------------------------------------


def cache_path() -> Path:
    env = os.environ.get(_CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-snp" / "autotune.json"


def load_cache(path: Optional[Path] = None) -> Dict[str, dict]:
    """The cache's ``{signature key: entry dict}`` map.  A missing file
    is an empty cache; an unreadable/corrupt one warns and degrades to
    empty (the planner falls through to the analytic model)."""
    path = cache_path() if path is None else Path(path)
    if not path.exists():
        return {}
    try:
        payload = json.loads(path.read_text())
        entries = payload["entries"]
        if not isinstance(entries, dict):
            raise TypeError("entries is not a mapping")
        return entries
    except Exception as exc:  # corrupt/poisoned file: degrade, don't crash
        warnings.warn(
            f"autotune cache {path} is unreadable ({exc}); ignoring it — "
            "planning falls back to the analytic cost model",
            UserWarning, stacklevel=2)
        return {}


def save_cache(entries: Dict[str, dict],
               path: Optional[Path] = None) -> None:
    """Atomic write (tmp + rename) so a crashed measure run can't leave a
    half-written file for :func:`load_cache` to choke on."""
    path = cache_path() if path is None else Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(
        {"version": _CACHE_VERSION, "entries": entries},
        indent=1, sort_keys=True))
    tmp.replace(path)


def _entry_to_choice(entry, source: Optional[str] = None
                     ) -> Optional[TunedChoice]:
    """Validated :class:`TunedChoice` from one cache entry, or ``None``
    for a poisoned entry (wrong types, unknown backend name, bad block
    values) — a bad entry is skipped, never fatal."""
    from .backend import available_backends  # lazy: backend imports plan
    try:
        if not isinstance(entry, dict):
            return None
        name = entry["backend"]
        if name not in available_backends():
            return None
        choice = TunedChoice(
            backend=str(name),
            encoding=str(entry.get("encoding", "auto")),
            hub_threshold=entry.get("hub_threshold"),
            block_b=entry.get("block_b"),
            block_t=entry.get("block_t"),
            block_n=entry.get("block_n"),
            us_per_step=entry.get("us_per_step"),
            source=source or str(entry.get("source", "cache")),
        )
        choice.kernel()  # raises on invalid block values
        if choice.encoding not in ("auto", "dense", "ell", "hybrid"):
            return None
        return choice
    except Exception:
        return None


def _choice_to_entry(choice: TunedChoice) -> dict:
    return {
        "backend": choice.backend,
        "encoding": choice.encoding,
        "hub_threshold": choice.hub_threshold,
        "block_b": choice.block_b,
        "block_t": choice.block_t,
        "block_n": choice.block_n,
        "us_per_step": choice.us_per_step,
        "source": choice.source,
    }


def store_choice(sig: WorkloadSignature, choice: TunedChoice,
                 path: Optional[Path] = None) -> None:
    """Persist ``choice`` as the winner for ``sig`` (exact-key entry)."""
    entries = load_cache(path)
    entries[sig.key()] = _choice_to_entry(choice)
    save_cache(entries, path)


# ---------------------------------------------------------------------------
# Bench-baseline seeding
# ---------------------------------------------------------------------------


def _baseline_path() -> Optional[Path]:
    env = os.environ.get(_BASELINE_ENV)
    if env:
        p = Path(env)
        return p if p.exists() else None
    p = Path(__file__).resolve().parents[3] / "BENCH_snp.json"
    return p if p.exists() else None


def _baseline_rows() -> List[Tuple[str, int, int, int, int, float]]:
    """``(backend, m, n, B, T, us_per_call)`` per single-device step row
    of the committed bench baseline (``snp_step`` + ``snp_step_large``
    tiers — the tiers whose rows time exactly one fused expansion)."""
    path = _baseline_path()
    if path is None:
        return []
    try:
        payload = json.loads(path.read_text())
        rows = payload["rows"]
    except Exception:
        return []
    out = []
    from .backend import available_backends  # lazy: backend imports plan
    names = available_backends()
    for row in rows:
        try:
            parts = str(row["name"]).split("/")
            if parts[0] not in ("snp_step", "snp_step_large"):
                continue
            shape = _ROW_SHAPE.search(parts[-1])
            backend = next(p for p in parts[1:] if p in names)
            if shape is None:
                continue
            m, n, B, T = map(int, shape.groups())
            out.append((backend, m, n, B, T, float(row["us_per_call"])))
        except Exception:
            continue
    return out


def _seed_entries() -> Dict[str, dict]:
    """Wildcard-kin cache entries from the committed baseline: per
    ``(m, n, B, T)`` shape, the fastest measured backend at the block
    shape the bench ran it with."""
    best: Dict[Tuple[int, int, int, int], Tuple[str, float]] = {}
    for backend, m, n, B, T, us in _baseline_rows():
        key = (m, n, B, T)
        if key not in best or us < best[key][1]:
            best[key] = (backend, us)
    entries = {}
    for (m, n, B, T), (backend, us) in best.items():
        cfg = _BENCH_KERNELS.get(backend)
        entries[f"m{m}_n{n}_kin*_B{B}_T{T}"] = _choice_to_entry(
            TunedChoice(
                backend=backend,
                block_b=cfg.block_b if cfg else None,
                block_t=cfg.block_t if cfg else None,
                block_n=cfg.block_n if cfg else None,
                us_per_step=us, source="seed"))
    return entries


def lookup(sig: WorkloadSignature, *,
           sharded: bool = False) -> Optional[TunedChoice]:
    """Cache consultation: exact signature key first, then the
    wildcard-kin key; measured disk entries shadow bench seeds.  Returns
    ``None`` on a miss (or when every hit is poisoned/unusable)."""
    disk = load_cache()
    seeds = _seed_entries()
    for key in (sig.key(), sig.wildcard_key()):
        for table, source in ((disk, None), (seeds, "seed")):
            if key in table:
                choice = _entry_to_choice(table[key], source=source)
                if choice is not None and _usable(
                        choice, sharded=sharded, semantics=sig.semantics):
                    return choice
    return None


def _usable(choice: TunedChoice, *, sharded: bool,
            semantics: str = "no_delays") -> bool:
    from .backend import get_backend
    sup = get_backend(choice.backend).supported_encodings(
        semantics=semantics)
    if sharded:
        return "sharded" in sup
    if not sup:
        return False
    return choice.encoding == "auto" or choice.encoding in sup


# ---------------------------------------------------------------------------
# Analytic cost model
# ---------------------------------------------------------------------------


def _fitted_curves() -> Dict[str, Tuple[float, float, float]]:
    """Per-backend ``(p, logA, Wmax)`` log-log least-squares fits of
    step cost against the work proxy ``W`` over the baseline rows
    (``us ≈ exp(logA)·W^p``); :data:`_FALLBACK_FITS` when no baseline
    file is readable."""
    pts: Dict[str, List[Tuple[float, float]]] = {}
    for backend, m, n, B, T, us in _baseline_rows():
        if us > 0:
            pts.setdefault(backend, []).append((float(B) * T * n * m, us))
    fits = {}
    for backend, ps in pts.items():
        lw = np.log([w for w, _ in ps])
        lu = np.log([u for _, u in ps])
        if len(ps) >= 2:
            p, logA = np.polyfit(lw, lu, 1)
        else:  # single point: assume the shared ~sqrt scaling exponent
            p = 0.5
            logA = float(lu[0] - p * lw[0])
        fits[backend] = (float(p), float(logA), max(w for w, _ in ps))
    return fits or dict(_FALLBACK_FITS)


def predict_us(sig: WorkloadSignature, backend: str) -> Optional[float]:
    """Model-predicted µs per fused step for ``backend`` at ``sig``, or
    ``None`` when the model has no curve for that backend."""
    fit = _fitted_curves().get(backend)
    if fit is None:
        return None
    p, logA, _ = fit
    return math.exp(logA + p * math.log(max(sig.work, 1.0)))


def model_choice(sig: WorkloadSignature, *,
                 sharded: bool = False) -> Optional[TunedChoice]:
    """Cheapest backend under the analytic model.  Interpret-mode Pallas
    backends are excluded once ``W`` leaves their fitted support
    (module constants) — their curves undersell how badly interpret
    overhead scales."""
    from .backend import available_backends, get_backend
    fits = _fitted_curves()
    names = available_backends()
    best: Optional[TunedChoice] = None
    for backend, (p, logA, wmax) in sorted(fits.items()):
        if backend not in names:
            continue
        sup = get_backend(backend).supported_encodings(
            semantics=sig.semantics)
        if not sup or (sharded and "sharded" not in sup):
            continue
        if (backend in _INTERPRET_KERNELS
                and sig.work > _EXTRAPOLATION_GUARD * wmax):
            continue
        us = math.exp(logA + p * math.log(max(sig.work, 1.0)))
        if best is None or us < best.us_per_step:
            cfg = _BENCH_KERNELS.get(backend)
            best = TunedChoice(
                backend=backend,
                block_b=cfg.block_b if cfg else None,
                block_t=cfg.block_t if cfg else None,
                block_n=cfg.block_n if cfg else None,
                us_per_step=us, source="model")
    return best


# ---------------------------------------------------------------------------
# Inline measurement (mode="measure")
# ---------------------------------------------------------------------------


def default_candidates(sig: WorkloadSignature, *,
                       sharded: bool = False) -> List[TunedChoice]:
    """The candidate grid :func:`measure_best` sweeps: every registered
    backend at its native encoding; kernel backends additionally at a
    couple of block shapes.  Interpret-mode kernels are dropped outside
    their trusted work range (same guard as the model) so a measure run
    on a large system doesn't spend minutes timing a known-bad config."""
    from .backend import available_backends, get_backend
    dense_blocks = [(8, 16, 128), (8, 32, 128)]
    sparse_blocks = [(8, 16, None), (4, 8, None)]
    out: List[TunedChoice] = []
    for name in sorted(available_backends()):
        sup = get_backend(name).supported_encodings(
            semantics=sig.semantics)
        if not sup or (sharded and "sharded" not in sup):
            continue
        if name in _INTERPRET_KERNELS:
            fit = _fitted_curves().get(name)
            wmax = fit[2] if fit else _FALLBACK_FITS.get(
                name, (0, 0, 0))[2]
            if sig.work > _EXTRAPOLATION_GUARD * wmax:
                continue
            blocks = dense_blocks if "dense" in sup else sparse_blocks
            out.extend(TunedChoice(backend=name, block_b=bb, block_t=bt,
                                   block_n=bn)
                       for bb, bt, bn in blocks)
        else:
            out.append(TunedChoice(backend=name))
    return out


def _time_step(be, comp, configs, T: int, *, reps: int) -> float:
    """Median µs of one fused expansion: one warmup call absorbs
    compilation, then ``reps`` timed ``block_until_ready`` calls."""
    import time

    import jax

    @jax.jit
    def fn(c):
        return be.expand(c, comp, max_branches=T)

    jax.block_until_ready(fn(configs))
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(configs))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def measure_best(system: SNPSystem, sig: WorkloadSignature, *,
                 num_shards: int = 1, reps: int = 3,
                 candidates: Optional[List[TunedChoice]] = None,
                 persist: bool = True) -> Optional[TunedChoice]:
    """Time the candidate grid on ``system`` at ``sig``'s ``(B, T)`` and
    return the winner (persisted to the cache so ``mode="auto"`` finds
    it next time).  Candidates that fail to compile/realize are skipped;
    ``None`` only when every candidate failed."""
    import jax.numpy as jnp

    from .backend import get_backend, resolve_kernel
    sharded = num_shards > 1
    cands = candidates if candidates is not None else \
        default_candidates(sig, sharded=sharded)
    rng = np.random.default_rng(0)
    m = system.num_neurons
    spikes = rng.integers(0, 5, size=(sig.B, m))
    if sig.semantics == "delays":
        # Delayed state rows are 3m wide: [spikes | countdown | pending].
        spikes = np.concatenate(
            [spikes, np.zeros((sig.B, 2 * m), spikes.dtype)], axis=1)
    configs = jnp.asarray(spikes, jnp.int32)
    best: Optional[TunedChoice] = None
    for cand in cands:
        try:
            # Measure at the single-device lowering even when planning a
            # sharded run: the per-shard kernel is the same body, and a
            # measure sweep must not commandeer the device mesh.
            plan = choice_to_plan(cand, system, mode="static",
                                  semantics=sig.semantics)
            be = resolve_kernel(get_backend(cand.backend), plan)
            comp = be.compile(system, plan=plan)
            us = _time_step(be, comp, configs, sig.T, reps=reps)
        except Exception:
            continue
        timed = dataclasses.replace(cand, us_per_step=us, source="measure")
        if best is None or us < best.us_per_step:
            best = timed
    if best is not None and persist:
        try:
            store_choice(sig, best)
        except OSError:
            pass  # read-only checkout: the measurement still stands
    return best


# ---------------------------------------------------------------------------
# Planner entry point
# ---------------------------------------------------------------------------


def choice_to_plan(choice: TunedChoice, system: SNPSystem, *,
                   num_shards: int = 1, mode: str = "auto",
                   semantics: str = "no_delays"
                   ) -> Optional[SystemPlan]:
    """A :class:`SystemPlan` realizing ``choice`` on ``system``, or
    ``None`` when the choice can't be realized (e.g. a cache entry naming
    an encoding its backend doesn't support under the semantics tier).
    ``encoding="auto"`` choices resolve sparse-family backends through
    the degree heuristic (ELL vs hybrid), everything else to the
    backend's native layout."""
    from .backend import get_backend
    sup = get_backend(choice.backend).supported_encodings(
        semantics=semantics)
    if not sup:
        return None
    if num_shards > 1:
        if "sharded" not in sup:
            return None
        # Hub regime: spread the heavy in-degree neurons across shards
        # (same degree test as the hybrid-encoding flip below).
        in_deg = _in_degrees(system)
        h = auto_hub_threshold(in_deg)
        kin = int(in_deg.max()) if in_deg.size else 0
        part = "degree" if kin > 2 * h else "contiguous"
        # Per-shard lowerings are ELL-only (compile_sharded).
        return SystemPlan(encoding="ell", num_shards=num_shards,
                          mode=mode, backend=choice.backend,
                          kernel=choice.kernel(), semantics=semantics,
                          partition=part)
    encoding, hub = choice.encoding, choice.hub_threshold
    if encoding == "auto" and sup[0] == "ell":
        in_deg = _in_degrees(system)
        h = auto_hub_threshold(in_deg)
        kin = int(in_deg.max()) if in_deg.size else 0
        if kin > 2 * h and "hybrid" in sup:
            encoding, hub = "hybrid", h
    if encoding != "auto" and encoding not in sup:
        return None
    return SystemPlan(encoding=encoding, hub_threshold=hub, mode=mode,
                      backend=choice.backend, kernel=choice.kernel(),
                      semantics=semantics)


def plan_for(system: SNPSystem, *, num_shards: int = 1,
             workload: Optional[Tuple[int, int]] = None,
             measure: bool = False,
             semantics: str = "no_delays") -> Optional[SystemPlan]:
    """The decision flow (module docstring): measure inline when asked,
    else cache → analytic model.  ``None`` sends the caller
    (``SystemPlan.for_system``) back to the static degree heuristic."""
    sig = signature_of(system, workload=workload, semantics=semantics)
    sharded = num_shards > 1
    if measure:
        choice = measure_best(system, sig, num_shards=num_shards)
        mode = "measure"
    else:
        choice = lookup(sig, sharded=sharded) \
            or model_choice(sig, sharded=sharded)
        mode = "auto"
    if choice is None:
        return None
    return choice_to_plan(choice, system, num_shards=num_shards, mode=mode,
                          semantics=semantics)
