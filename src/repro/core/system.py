"""Definitions of Spiking Neural P systems (without delays).

This module is the *specification* layer: plain-Python dataclasses describing
an SNP system exactly as in Definition 1 of the paper (Cabarle, Adorna,
Martínez-del-Amor 2011).  The numeric/JAX layer lives in
:mod:`repro.core.matrix` and :mod:`repro.core.semantics`.

Rule regular expressions.  Every regular language over the unary alphabet
``{a}`` is a finite union of arithmetic progressions; a single rule here
carries one progression ``L(E) = { base + t * period : t >= 0 }`` (with
``period = 0`` meaning the single word ``a^base``).  Unions are expressed by
giving a neuron several rules with identical action.  Two membership modes
are supported (see DESIGN.md §1.1):

* ``exact``    — standard SNP semantics: applicable iff ``spikes ∈ L(E)``.
* ``covering`` — the paper's implemented (b-3) semantics: applicable iff
  ``spikes >= base`` (and, for ``period > 0``, the progression also matches
  some value ``<= spikes``; with ``period == 0`` it is a plain threshold).
  The paper's printed trace of Π requires this mode (DESIGN.md §1.2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

__all__ = ["Rule", "SNPSystem", "paper_pi"]


@dataclass(frozen=True)
class Rule:
    """One rule ``E / a^consume -> a^produce`` owned by ``neuron``.

    ``produce == 0`` encodes a forgetting rule ``a^s -> λ`` (with
    ``consume = s``).  ``regex_base``/``regex_period`` encode ``E`` as the
    arithmetic progression ``{base + t*period}``; ``covering`` selects the
    membership mode (see module docstring).

    ``delay`` is the rule's firing delay ``d`` from the general SNP
    definition (arXiv 1212.2529): firing closes the owning neuron for ``d``
    steps and its spikes land when it reopens.  The paper's matrix
    formalism (and every default code path) requires ``d == 0``; systems
    with ``delay > 0`` only compile under ``SystemPlan(semantics="delays")``
    (DESIGN.md §2 "Delayed semantics").
    """

    neuron: int
    consume: int
    produce: int
    regex_base: int
    regex_period: int = 0
    covering: bool = False
    delay: int = 0

    def __post_init__(self) -> None:
        if self.neuron < 0:
            raise ValueError(f"neuron index must be >= 0, got {self.neuron}")
        if self.consume < 1:
            raise ValueError(f"consume must be >= 1, got {self.consume}")
        if self.produce < 0:
            raise ValueError(f"produce must be >= 0, got {self.produce}")
        if self.regex_base < self.consume:
            # a^k ∈ L(E) requires k >= c for the rule to be usable at all.
            raise ValueError(
                f"regex base {self.regex_base} < consume {self.consume}: "
                "rule could fire with fewer spikes than it consumes"
            )
        if self.regex_period < 0:
            raise ValueError("regex_period must be >= 0")
        if not 0 <= self.delay < 1 << 15:
            # The sparse lowering packs (produce | delay << 16) into one
            # int32; any realistic delay is orders of magnitude smaller.
            raise ValueError(
                f"delay must be in [0, 2^15), got {self.delay}")

    @property
    def is_forgetting(self) -> bool:
        return self.produce == 0

    def describe(self) -> str:
        e = f"a^{self.regex_base}"
        if self.regex_period:
            e += f"(a^{self.regex_period})*"
        if self.covering:
            e += "(>=)"
        rhs = f"a^{self.produce}" if self.produce else "λ"
        if self.delay:
            rhs += f"; {self.delay}"
        return f"σ{self.neuron}: {e}/a^{self.consume} -> {rhs}"


@dataclass(frozen=True)
class SNPSystem:
    """An SNP system without delays, ``Π = (O, σ_1..σ_m, syn, in, out)``."""

    num_neurons: int
    initial_spikes: Tuple[int, ...]
    rules: Tuple[Rule, ...]
    synapses: Tuple[Tuple[int, int], ...]
    input_neuron: int = -1  # -1: none
    output_neuron: int = -1  # -1: none
    name: str = "snp"

    def __post_init__(self) -> None:
        m = self.num_neurons
        if m < 1:
            raise ValueError("need at least one neuron")
        if len(self.initial_spikes) != m:
            raise ValueError(
                f"initial_spikes has {len(self.initial_spikes)} entries, "
                f"expected {m}"
            )
        if any(s < 0 for s in self.initial_spikes):
            raise ValueError("initial spike counts must be >= 0")
        for i, j in self.synapses:
            if not (0 <= i < m and 0 <= j < m):
                raise ValueError(f"synapse ({i},{j}) out of range")
            if i == j:
                raise ValueError(f"self-synapse ({i},{j}) not allowed")
        if len(set(self.synapses)) != len(self.synapses):
            raise ValueError("duplicate synapses")
        for r in self.rules:
            if r.neuron >= m:
                raise ValueError(f"rule {r} refers to missing neuron")
        for idx in (self.input_neuron, self.output_neuron):
            if idx != -1 and not (0 <= idx < m):
                raise ValueError(f"in/out neuron {idx} out of range")

    # -- convenience -------------------------------------------------------

    @property
    def num_rules(self) -> int:
        return len(self.rules)

    @property
    def max_delay(self) -> int:
        """Largest per-rule firing delay (0 for a paper-style system)."""
        return max((r.delay for r in self.rules), default=0)

    def rules_of(self, neuron: int) -> List[Rule]:
        return [r for r in self.rules if r.neuron == neuron]

    def out_degree(self, neuron: int) -> int:
        return sum(1 for (i, _) in self.synapses if i == neuron)

    def with_mode(self, covering: bool) -> "SNPSystem":
        """Return a copy with every rule's membership mode replaced."""
        rules = tuple(dataclasses.replace(r, covering=covering) for r in self.rules)
        return dataclasses.replace(self, rules=rules)

    def describe(self) -> str:
        lines = [f"SNP system '{self.name}': m={self.num_neurons} "
                 f"n={self.num_rules} out={self.output_neuron}"]
        lines += [f"  ({k + 1}) {r.describe()}" for k, r in enumerate(self.rules)]
        lines.append(f"  syn = {sorted(self.synapses)}")
        lines.append(f"  C0  = {list(self.initial_spikes)}")
        return "\n".join(lines)


def paper_pi(covering: bool = True) -> SNPSystem:
    """The paper's Fig. 1 system Π generating ℕ∖{1}.

    Total rule order (1)..(5) as in the paper's ``M_Π`` (eq. 1):

    1. σ1: a^2/a   -> a
    2. σ1: a^2/a^2 -> a
    3. σ2: a/a     -> a
    4. σ3: a/a     -> a      (to the environment)
    5. σ3: a^2     -> λ

    ``covering=True`` reproduces the paper's simulator ((b-3) ``>=``
    semantics, matching its printed ``allGenCk``); ``covering=False`` is the
    standard exact semantics under which Π generates exactly ℕ∖{1}.
    """
    rules = (
        Rule(neuron=0, consume=1, produce=1, regex_base=2, covering=covering),
        Rule(neuron=0, consume=2, produce=1, regex_base=2, covering=covering),
        Rule(neuron=1, consume=1, produce=1, regex_base=1, covering=covering),
        Rule(neuron=2, consume=1, produce=1, regex_base=1, covering=covering),
        Rule(neuron=2, consume=2, produce=0, regex_base=2, covering=covering),
    )
    return SNPSystem(
        num_neurons=3,
        initial_spikes=(2, 1, 1),
        rules=rules,
        synapses=((0, 1), (0, 2), (1, 0), (1, 2)),
        output_neuron=2,
        name="paper-pi" + ("-covering" if covering else "-exact"),
    )
