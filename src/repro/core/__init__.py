"""Core SNP-system engine: the paper's contribution as a composable module.

Public API:

* :class:`repro.core.system.SNPSystem`, :class:`repro.core.system.Rule` —
  system specification (paper Definition 1).
* :func:`repro.core.matrix.compile_system` — dense matrix encoding (paper
  §2.2); :func:`repro.core.matrix.compile_system_sparse` — ELL/segment
  encoding for large bounded-degree systems (no ``O(n·m)`` arrays).
* :mod:`repro.core.semantics` — batched applicability / spiking-vector
  enumeration / transition (paper eq. 2, Alg. 2), dense and sparse.
* :mod:`repro.core.backend` — pluggable step backends (``"ref"`` jnp
  oracle / ``"pallas"`` fused kernel / ``"sparse"`` ELL gather /
  ``"sparse_pallas"`` fused sparse kernel) behind one registry; every
  consumer takes ``backend=`` and lowers via ``backend.compile``.
* :mod:`repro.core.plan` — partition/encoding planning
  (:class:`~repro.core.plan.SystemPlan`): per-block encoding choice
  (dense / ELL / hybrid ELL+COO for heavy-tailed graphs) and the optional
  neuron-axis partition (:func:`~repro.core.plan.compile_sharded`)
  consumed by ``explore_distributed``.  Every ``backend.compile`` and
  consumer entry point accepts ``plan=``; the default plan is
  bit-identical to the historical encodings.
* :func:`repro.core.engine.explore` — computation-tree BFS (paper Alg. 1)
  as one on-device ``lax.while_loop``.
* :mod:`repro.core.hashtable` — device-resident open-addressing hash
  table (batched insert-if-absent in one jitted call) backing the BFS
  visited set: ``O(wave·probe)`` dedup instead of re-sorting the visited
  arrays every wave, on one chip and per shard in the distributed runs.
* :func:`repro.core.engine.run_traces` — batched trajectory serving.
* :mod:`repro.core.distributed` — multi-chip workloads (shard_map):
  ``explore_distributed`` (hash-partitioned BFS) and
  ``run_traces_distributed`` (data-parallel trace serving, DESIGN.md §4).
* :mod:`repro.core.generators` — synthetic system families for scaling.
* :mod:`repro.core.autotune` — the query planner behind
  ``SystemPlan.for_system(mode="auto"|"measure")``: autotune cache
  (seeded from the committed bench baseline) → analytic cost model →
  degree heuristic, plus the inline ``(bb, bt)`` sweep.  Entry points
  default to ``backend=None`` = "let the planner pick".
"""

from .backend import (PallasBackend, RefBackend, SparseBackend,
                      SparsePallasBackend, StepBackend, available_backends,
                      get_backend, lower_with_backend, register_backend,
                      resolve_entry, resolve_entry_info, resolve_kernel,
                      supported_under, supports_sharded)
from .engine import (ExploreResult, TraceOut, emission_gaps, explore,
                     resolve_dedup, run_trace, run_traces, successor_set)
from .failover import (DEGRADE_ORDER, DegradeEvent, add_degrade_listener,
                       degrade_candidates, remove_degrade_listener,
                       run_with_failover)
from .generators import with_delays
from .hashtable import (HashTable, first_occurrence, insert_if_absent,
                        insert_unique, lookup, make_table, table_slots)
from .matrix import (CompiledSNP, CompiledSparseSNP, compile_system,
                     compile_system_sparse, is_compiled, is_delayed)
from .plan import (DenseShardArrays, KernelConfig, ShardedCompiled,
                   SystemPlan, auto_hub_threshold, compile_sharded,
                   is_sharded, lower_shard_dense, partition_neurons,
                   partition_stats)
from .semantics import (applicability, branch_info, delayed_next_configs,
                        next_configs, sparse_delayed_next_configs,
                        sparse_next_configs, spiking_vectors, split_state)
from .system import Rule, SNPSystem, paper_pi

__all__ = [
    "SNPSystem", "Rule", "paper_pi",
    "CompiledSNP", "CompiledSparseSNP", "compile_system",
    "compile_system_sparse", "is_compiled", "is_delayed",
    "SystemPlan", "KernelConfig", "ShardedCompiled", "DenseShardArrays",
    "auto_hub_threshold", "compile_sharded", "is_sharded",
    "lower_shard_dense", "partition_neurons", "partition_stats",
    "HashTable", "make_table", "table_slots", "lookup", "first_occurrence",
    "insert_unique", "insert_if_absent",
    "applicability", "branch_info", "next_configs", "sparse_next_configs",
    "spiking_vectors", "split_state", "delayed_next_configs",
    "sparse_delayed_next_configs", "with_delays",
    "StepBackend", "RefBackend", "PallasBackend", "SparseBackend",
    "SparsePallasBackend",
    "register_backend", "get_backend", "available_backends",
    "lower_with_backend", "resolve_entry", "resolve_entry_info",
    "resolve_kernel", "supported_under", "supports_sharded",
    "DEGRADE_ORDER", "DegradeEvent", "add_degrade_listener",
    "degrade_candidates", "remove_degrade_listener", "run_with_failover",
    "explore", "resolve_dedup", "ExploreResult", "TraceOut", "successor_set",
    "emission_gaps", "run_trace", "run_traces",
]
