"""Device-resident open-addressing hash table for BFS frontier dedup.

The sort-based dedup the engine launched with re-sorts the *entire*
visited-hash set against every candidate wave — ``O((V+C)·log(V+C))`` per
BFS level even when the wave only carries ``C = F·T`` candidates.  This
module replaces it with a power-of-two-sized open-addressing table
(linear probing) whose per-wave cost is ``O(C · probe)`` gathers and
scatters, independent of how many configurations are already visited —
the structure the sparse follow-up work keeps device-resident so the
whole BFS can run as one jitted loop (DESIGN.md §2 "Device-resident
dedup").

Layout: three parallel arrays of ``S = 2^k`` slots —

* ``slots_hi`` / ``slots_lo`` — the two uint32 lanes of the stored 64-bit
  config hash (:func:`repro.core.hashing.config_hash` /
  :func:`~repro.core.hashing.zobrist_hash`);
* ``slot_payload`` — caller payload (the engine stores the archive row of
  the inserted configuration, making the table a hash *map*).

An empty slot holds ``(SENTINEL, SENTINEL)`` in both lanes.  A *real* key
equal to that pair (probability 2^-64) is remapped to
``(SENTINEL, SENTINEL - 1)`` before probing — deterministic on both the
insert and lookup sides, so the remap is invisible except for an equally
improbable alias with the remap target (the same birthday-level risk the
64-bit hash already carries).

Probing is linear from a mixed base slot, bounded by ``max_probes``;
every batched operation is a single ``lax.while_loop`` whose carry is the
pending-candidate mask, so resolved candidates stop paying.  A candidate
that exhausts its probe budget resolves conservatively (lookup: absent;
insert: not inserted) and raises the operation's **overflow flag**, which
the engine folds into its ``visited_overflow`` reporting — bounded probes
are never a silent drop.

Batched-duplicate discipline (what makes archives bit-identical to the
sort-based path): within one wave, only the *lowest-indexed* candidate of
an equal-hash group counts as new — exactly the verdict the sorted path's
``(hash, is_cand, payload)`` sort produced.  Claim races are resolved by
scatter-min on the candidate index, and a claim loser re-checks the slot
it lost (the winner's key may be its own) before probing onward.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import SENTINEL, _fmix32

__all__ = ["HashTable", "table_slots", "make_table", "lookup",
           "first_occurrence", "insert_unique", "insert_if_absent"]

_MIX = np.uint32(0x9E3779B1)


class HashTable(NamedTuple):
    """Open-addressing hash table state (a pytree — rides ``jit``,
    ``lax.while_loop`` carries and checkpoint snapshots unchanged)."""

    slots_hi: jnp.ndarray      # (S,) uint32 — SENTINEL when empty (with lo)
    slots_lo: jnp.ndarray      # (S,) uint32
    slot_payload: jnp.ndarray  # (S,) int32 — caller payload (-1 when empty)
    count: jnp.ndarray         # () int32 — live keys

    @property
    def num_slots(self) -> int:
        return self.slots_hi.shape[0]


def table_slots(capacity: int) -> int:
    """Power-of-two slot count for ``capacity`` keys at load factor
    <= 0.5 (linear probing stays O(1) expected below that)."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    return max(16, 1 << (2 * capacity - 1).bit_length())


def make_table(capacity: int) -> HashTable:
    """An empty table sized for ``capacity`` keys (``table_slots`` slots)."""
    s = table_slots(capacity)
    return HashTable(
        slots_hi=jnp.full((s,), SENTINEL, jnp.uint32),
        slots_lo=jnp.full((s,), SENTINEL, jnp.uint32),
        slot_payload=jnp.full((s,), -1, jnp.int32),
        count=jnp.asarray(0, jnp.int32),
    )


def _default_probes(num_slots: int) -> int:
    # Expected probe length at load 0.5 is ~2.5; 64 covers pathological
    # clustering with margin while keeping the worst-case loop bounded.
    return min(num_slots, 64)


def _canonical(hi, lo, valid):
    """Invalid lanes -> the empty marker; a real key equal to the empty
    marker -> ``(SENTINEL, SENTINEL - 1)`` (module docstring)."""
    hi = jnp.asarray(hi, jnp.uint32)
    lo = jnp.asarray(lo, jnp.uint32)
    collide = (hi == SENTINEL) & (lo == SENTINEL)
    lo = jnp.where(valid & collide, lo - np.uint32(1), lo)
    hi = jnp.where(valid, hi, SENTINEL)
    lo = jnp.where(valid, lo, SENTINEL)
    return hi, lo


def _base_slot(hi, lo, num_slots: int):
    """uint32 base slot: both lanes avalanched together so probe chains of
    distinct keys decorrelate even when one lane collides."""
    mask = np.uint32(num_slots - 1)
    return _fmix32(hi ^ (lo * _MIX)) & mask


def lookup(table: HashTable, hi, lo, valid,
           max_probes: Optional[int] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched membership probe (no writes).

    Returns ``(found, payload)``: ``found[i]`` iff key ``i`` is stored
    (``valid[i]`` required), ``payload[i]`` its stored payload (-1
    otherwise).  A probe chain that exhausts ``max_probes`` occupied,
    non-matching slots resolves as absent — sound, because ``insert``
    bounds its probes identically, so no stored key lives beyond the
    bound."""
    S = table.num_slots
    D = _default_probes(S) if max_probes is None else min(max_probes, S)
    hi, lo = _canonical(hi, lo, valid)
    base = _base_slot(hi, lo, S)
    mask = np.uint32(S - 1)
    K = hi.shape[0]

    def cond(c):
        p, pending, _, _ = c
        return (p < D) & jnp.any(pending)

    def body(c):
        p, pending, found, payload = c
        slot = ((base + p.astype(jnp.uint32)) & mask).astype(jnp.int32)
        cur_hi = table.slots_hi[slot]
        cur_lo = table.slots_lo[slot]
        match = pending & (cur_hi == hi) & (cur_lo == lo)
        empty = (cur_hi == SENTINEL) & (cur_lo == SENTINEL)
        found = found | match
        payload = jnp.where(match, table.slot_payload[slot], payload)
        pending = pending & ~match & ~empty
        return p + 1, pending, found, payload

    _, _, found, payload = jax.lax.while_loop(
        cond, body,
        (jnp.asarray(0, jnp.int32), jnp.asarray(valid, bool),
         jnp.zeros((K,), bool), jnp.full((K,), -1, jnp.int32)))
    return found, payload


def _claim_loop(slots_hi, slots_lo, payloads, hi, lo, pending0,
                payload_vals, max_probes: int):
    """Shared batched claim-insert loop (runs on the real table for
    ``insert_unique``, on a per-wave scratch for ``first_occurrence``).

    Per iteration each pending candidate gathers its current slot and
    either (a) matches the stored key — resolved as a duplicate, (b) wins
    an empty-slot claim (scatter-min on candidate index) — resolved as
    inserted, (c) loses a claim — re-checks the *same* slot next
    iteration (the winner may hold its key), or (d) sees an occupied
    foreign key — advances one probe.  Candidates whose probe counter
    reaches ``max_probes`` resolve as overflowed.

    Returns ``(slots_hi, slots_lo, payloads, won, dup, overflow)``.
    """
    S = slots_hi.shape[0]
    K = hi.shape[0]
    mask = np.uint32(S - 1)
    base = _base_slot(hi, lo, S)
    idx = jnp.arange(K, dtype=jnp.int32)
    # every advance or claim-loss consumes an iteration; a loss is
    # followed by a resolution or an advance, so 2*D + 1 bounds the loop
    iter_cap = 2 * max_probes + 1

    def cond(c):
        it, pending = c[0], c[1]
        return (it < iter_cap) & jnp.any(pending)

    def body(c):
        it, pending, probe, won, dup, ovf, s_hi, s_lo, s_pay = c
        slot = ((base + probe.astype(jnp.uint32)) & mask).astype(jnp.int32)
        cur_hi = s_hi[slot]
        cur_lo = s_lo[slot]
        match = pending & (cur_hi == hi) & (cur_lo == lo)
        empty = (cur_hi == SENTINEL) & (cur_lo == SENTINEL)
        try_claim = pending & ~match & empty
        claim = jnp.full((S,), K, jnp.int32).at[slot].min(
            jnp.where(try_claim, idx, K))
        win = try_claim & (claim[slot] == idx)
        wslot = jnp.where(win, slot, S)
        s_hi = s_hi.at[wslot].set(hi, mode="drop")
        s_lo = s_lo.at[wslot].set(lo, mode="drop")
        s_pay = s_pay.at[wslot].set(payload_vals, mode="drop")
        # occupied-by-foreign-key -> advance; claim losers hold position
        advance = pending & ~match & ~empty
        probe = probe + advance.astype(jnp.int32)
        out = probe >= max_probes
        return (it + 1, pending & ~match & ~win & ~out, probe,
                won | win, dup | match, ovf | (pending & out),
                s_hi, s_lo, s_pay)

    init = (jnp.asarray(0, jnp.int32), jnp.asarray(pending0, bool),
            jnp.zeros((K,), jnp.int32), jnp.zeros((K,), bool),
            jnp.zeros((K,), bool), jnp.zeros((K,), bool),
            slots_hi, slots_lo, payloads)
    (_, _, _, won, dup, ovf, s_hi, s_lo, s_pay) = jax.lax.while_loop(
        cond, body, init)
    return s_hi, s_lo, s_pay, won, dup, jnp.any(ovf)


def first_occurrence(hi, lo, valid,
                     max_probes: Optional[int] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``first[i]`` iff candidate ``i`` is the lowest-indexed holder of
    its key within the batch (the sorted path's intra-wave verdict).
    Runs the claim loop on a throwaway scratch table sized ``O(K)`` —
    per-wave cost never scales with the visited-set size.  Returns
    ``(first, overflow)``."""
    K = int(hi.shape[0])
    S = table_slots(max(K, 1))
    D = _default_probes(S) if max_probes is None else min(max_probes, S)
    hi, lo = _canonical(hi, lo, valid)
    s_hi = jnp.full((S,), SENTINEL, jnp.uint32)
    s_lo = jnp.full((S,), SENTINEL, jnp.uint32)
    s_pay = jnp.zeros((S,), jnp.int32)
    _, _, _, won, _, ovf = _claim_loop(
        s_hi, s_lo, s_pay, hi, lo, jnp.asarray(valid, bool),
        jnp.zeros_like(hi, jnp.int32), D)
    return won, ovf


def insert_unique(table: HashTable, hi, lo, mask, payload=None,
                  max_probes: Optional[int] = None
                  ) -> Tuple[HashTable, jnp.ndarray, jnp.ndarray]:
    """Insert masked keys (expected distinct and absent — the engine
    inserts only selected first-occurrence candidates that failed
    ``lookup``).  A key found present anyway (possible only when a
    bounded lookup under-reported) is left in place and reported as not
    inserted.  Returns ``(table, inserted, overflow)``."""
    if payload is None:
        payload = jnp.arange(hi.shape[0], dtype=jnp.int32)
    D = (_default_probes(table.num_slots) if max_probes is None
         else min(max_probes, table.num_slots))
    hi, lo = _canonical(hi, lo, mask)
    s_hi, s_lo, s_pay, won, _, ovf = _claim_loop(
        table.slots_hi, table.slots_lo, table.slot_payload, hi, lo,
        jnp.asarray(mask, bool), jnp.asarray(payload, jnp.int32), D)
    new_count = table.count + jnp.sum(won, dtype=jnp.int32)
    return (HashTable(s_hi, s_lo, s_pay, new_count), won, ovf)


def insert_if_absent(table: HashTable, hi, lo, valid, payload=None,
                     max_probes: Optional[int] = None
                     ) -> Tuple[HashTable, jnp.ndarray, jnp.ndarray]:
    """One-call batched insert-if-absent: membership lookup, intra-batch
    first-occurrence, then insertion of the genuinely-new keys.  Returns
    ``(table, is_new, overflow)`` where ``is_new[i]`` iff key ``i`` was
    absent *and* is its batch group's first occurrence (it is now
    stored).  The engine uses the three phases directly so it can cap
    insertions at the frontier width between phases; this wrapper is the
    uncapped composition (property tests, small callers)."""
    found, _ = lookup(table, hi, lo, valid, max_probes)
    first, ovf_f = first_occurrence(hi, lo, valid, max_probes)
    is_new = jnp.asarray(valid, bool) & first & ~found
    table, inserted, ovf_i = insert_unique(table, hi, lo, is_new, payload,
                                           max_probes)
    return table, inserted, ovf_f | ovf_i


@functools.partial(jax.jit, static_argnames=("capacity",))
def _jit_make(capacity: int) -> HashTable:
    return make_table(capacity)
