"""64-bit configuration hashing (2 x uint32 lanes) for on-device dedup.

The paper dedups configurations with a host-side Python list of strings.
At fleet scale the visited set must live on device and shard across chips,
so configurations are hashed to 64 bits: a murmur3-style finalizer applied
per element, folded with two independent polynomial accumulators.  Collision
probability for ``N`` distinct configs is ~``N^2 / 2^65`` (≈ 5e-7 for ten
million configs).  The host-side exact archive (``ExploreResult.archive``)
lets tests cross-validate hash dedup on small systems.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["config_hash", "zobrist_hash", "SENTINEL"]

# Sorts after every real hash; used for invalid / empty slots.
SENTINEL = np.uint32(0xFFFFFFFF)

_GOLDEN = np.uint32(0x9E3779B9)
_P1 = np.uint32(0x01000193)  # FNV prime
_P2 = np.uint32(0x85EBCA77)


def _fmix32(x: jnp.ndarray) -> jnp.ndarray:
    x = x ^ (x >> 16)
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _pow_vector(p: np.uint32, m: int) -> np.ndarray:
    """[p^(m-1), ..., p^1, p^0] mod 2^32 (computed exactly in Python ints)."""
    out = np.empty(m, dtype=np.uint64)
    acc = 1
    for i in range(m - 1, -1, -1):
        out[i] = acc
        acc = (acc * int(p)) % (1 << 32)
    return out.astype(np.uint32)


def config_hash(configs: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Hash int32 configs (..., m) to two uint32 lanes (hi, lo).

    Pure function of the config values; wraparound uint32 arithmetic.
    Per-element mixing is a single multiply + shift-xor (the full murmur
    finalizer runs only on the two accumulators): hashing is ~half of the
    SNP step's HBM traffic at scale, and the two independent polynomial
    lanes with position salts already give 2^-64-grade collision behavior
    (EXPERIMENTS.md §Perf cell C, iteration 2).
    """
    m = configs.shape[-1]
    x = configs.astype(jnp.uint32)
    pos = (np.arange(m, dtype=np.uint64) * int(_GOLDEN) % (1 << 32)).astype(
        np.uint32
    )
    y = (x + pos) * np.uint32(0x85EBCA6B)
    y = y ^ (y >> 16)
    p1 = jnp.asarray(_pow_vector(_P1, m))
    p2 = jnp.asarray(_pow_vector(_P2, m))
    h1 = jnp.sum(y * p1, axis=-1, dtype=jnp.uint32)
    h2 = jnp.sum((y ^ _GOLDEN) * p2, axis=-1, dtype=jnp.uint32)
    hi = _fmix32(h1 ^ np.uint32(m))
    m_mix = np.uint32((m * int(_GOLDEN)) % (1 << 32))
    lo = _fmix32(h2 + m_mix)
    return hi, lo


_Z1 = np.uint32(0x9E3779B1)
_Z2 = np.uint32(0x85EBCA77)
_ZV1 = np.uint32(0x27D4EB2F)
_ZV2 = np.uint32(0x165667B1)


def zobrist_hash(configs: jnp.ndarray, offset=0,
                 positions=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sum-combinable (Zobrist-style) 2 x uint32 hash of config *slices*.

    Each (global position, value) pair is mixed through the murmur
    finalizer independently and the lanes are **summed** (mod 2^32), so
    partial hashes of disjoint neuron ranges *add up* to the hash of the
    concatenated configuration:

        ``zobrist(c) == Σ_d zobrist(c[lo_d:hi_d], offset=lo_d)``

    That additivity is what the neuron-axis-sharded frontier needs — each
    device hashes only its ``(..., mloc)`` slice (``offset`` = its global
    neuron offset, may be traced) and one ``psum`` yields the global hash
    (DESIGN.md §2).  Weaker ordering structure than :func:`config_hash`'s
    polynomial lanes, but each summand is fully avalanched, so collisions
    stay at the 2^-64 birthday level.

    ``positions`` (shape ``(k,)``, overrides ``offset``) gives the global
    neuron index of each column explicitly — the degree-weighted
    partition scatters neurons across shards, so a shard's columns are no
    longer a contiguous range.  ``positions=offset + arange(k)`` is
    exactly the ``offset`` form, so contiguous shards hash bit-identically
    through either spelling.
    """
    x = configs.astype(jnp.uint32)
    k = configs.shape[-1]
    if positions is not None:
        pos = jnp.asarray(positions).astype(jnp.uint32) + jnp.uint32(1)
    else:
        pos = jnp.arange(k, dtype=jnp.uint32) + \
            jnp.asarray(offset, dtype=jnp.uint32) + jnp.uint32(1)
    hi = jnp.sum(_fmix32((pos * _Z1) ^ (x * _ZV1)), axis=-1,
                 dtype=jnp.uint32)
    lo = jnp.sum(_fmix32((pos * _Z2) + (x * _ZV2) + _GOLDEN), axis=-1,
                 dtype=jnp.uint32)
    return hi, lo
