"""Breadth-first exploration of an SNP system's computation tree.

Implements Algorithm 1 of the paper as a single-device, fully on-device
loop: the whole BFS is one jitted ``lax.while_loop`` whose body expands the
frontier, hashes every successor, dedups against the visited set
(sort-based, exactly-once emission), and compacts the new configurations
into the next frontier.  The host syncs exactly once — to read the final
archive — so the paper's host/device ping-pong (strings to Python, vectors
back) is gone entirely, including the per-level ``frontier_n`` poll the
first version of this engine still paid (DESIGN.md §2).

The transition itself is pluggable: every entry point takes a ``backend=``
(name or :class:`~repro.core.backend.StepBackend`) selecting how successors
are expanded — ``"ref"`` (pure-jnp oracle), ``"pallas"`` (fused dense
kernel), or ``"sparse"``/``"sparse_pallas"`` (ELL gather/segment-sum for
large bounded-degree systems); see :mod:`repro.core.backend`.  Each
backend also owns its lowering: pass an :class:`SNPSystem` and the engine
calls ``backend.compile`` (dense or sparse encoding as appropriate), or
pass a pre-compiled object to reuse it across calls.  Backends agree
bit-for-bit on valid entries, so archives and traces are
backend-independent.

Static-shape discipline: the frontier capacity ``F``, branch fan-out cap
``T`` and visited/archive capacity ``V`` are compile-time constants; all
overflow conditions are detected and reported, never silently dropped:

* ``branch_overflow``   — some config had Ψ > T (only its first T branches
  were explored);
* ``frontier_overflow`` — more than F new configs in one step.  The excess
  are *not* marked visited, so they are re-generated and expanded later:
  exploration stays sound, only the "discovered" count may double-count;
* ``visited_overflow``  — visited set is full; same soundness argument.

The multi-chip versions live in :mod:`repro.core.distributed`:
hash-partitioned BFS (``explore_distributed``) and data-parallel batched
trace serving (``run_traces_distributed``, bit-identical to
:func:`run_traces` — DESIGN.md §4).  The serving front end over
:func:`run_traces` (request batching, async futures drain) is
:class:`repro.serve.snp_service.SNPTraceService`.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .backend import (BackendLike, compile_with_plan, get_backend,
                      lower_with_backend, resolve_entry_info)
from .failover import run_with_failover
from .hashing import SENTINEL, config_hash
from .hashtable import (HashTable, first_occurrence, insert_unique, lookup,
                        make_table)
from .matrix import CompiledAny, is_compiled
from .plan import SystemPlan
from .system import SNPSystem

__all__ = ["ExploreState", "ExploreResult", "TraceOut", "explore",
           "resolve_dedup", "successor_set", "emission_gaps", "run_trace",
           "run_traces"]


def _resolve_comp(system, be, plan: Optional[SystemPlan]) -> CompiledAny:
    """Single-device lowering: a pre-compiled encoding passes through the
    backend's ``lower`` hook (so an encoding the backend's kernel cannot
    realize raises instead of being silently reinterpreted), an
    ``SNPSystem`` lowers via ``backend.compile(system, plan=...)``.  Plans
    asking for a neuron-axis partition belong to ``explore_distributed``."""
    if plan is not None and plan.num_shards > 1:
        raise ValueError(
            "plan.num_shards > 1 (neuron-axis sharding) is only consumed "
            "by repro.core.distributed.explore_distributed")
    return lower_with_backend(be, system, plan) if is_compiled(system) \
        else compile_with_plan(be, system, plan)


class ExploreState(NamedTuple):
    """Full BFS device state.  The visited-set representation depends on
    the (static) ``dedup`` mode: ``"hash"`` stores open-addressing table
    slots (``visited_hi/lo/payload`` are ``(S,)`` with ``S =
    table_slots(V)``, ``visited_n`` the live-key count), ``"sort"`` the
    historical lexicographically-sorted ``(V,)`` hash arrays (payload is
    a zero-length placeholder).  Either way the state is one pytree, so
    checkpoint snapshots carry the dedup structure with no special
    casing — a resume rebuilds the table bit-identically."""

    frontier: jnp.ndarray       # (F, m) int32
    frontier_n: jnp.ndarray     # () int32 — valid prefix length
    visited_hi: jnp.ndarray     # (V,)|(S,) uint32 — see docstring
    visited_lo: jnp.ndarray     # (V,)|(S,) uint32
    visited_payload: jnp.ndarray  # (S,)|(0,) int32 — archive row per slot
    visited_n: jnp.ndarray      # () int32
    archive: jnp.ndarray        # (V, m) int32 — discovery order
    archive_n: jnp.ndarray      # () int32
    step: jnp.ndarray           # () int32
    branch_overflow: jnp.ndarray    # () bool
    frontier_overflow: jnp.ndarray  # () bool
    visited_overflow: jnp.ndarray   # () bool


@dataclass(frozen=True)
class ExploreResult:
    configs: np.ndarray         # (n_discovered, m) in discovery order
    num_discovered: int
    steps: int
    exhausted: bool             # True => tree fully explored (no overflow, frontier drained)
    branch_overflow: bool
    frontier_overflow: bool
    visited_overflow: bool

    def as_strings(self) -> List[str]:
        """Configs in the paper's ``allGenCk`` 'a-b-c' string format."""
        return ["-".join(str(int(v)) for v in row) for row in self.configs]


def _init_state(comp: CompiledAny, frontier_cap: int, visited_cap: int,
                init: Optional[jnp.ndarray] = None,
                dedup: str = "hash") -> ExploreState:
    # State row width: m for the paper's systems, 3m under delayed
    # semantics ([spikes | countdown | pending] — DESIGN.md).
    m = getattr(comp, "state_width", comp.num_neurons)
    c0 = comp.init_config if init is None else jnp.asarray(init, jnp.int32)
    frontier = jnp.zeros((frontier_cap, m), jnp.int32).at[0].set(c0)
    hi0, lo0 = config_hash(c0)
    if dedup == "hash":
        table, _, _ = insert_unique(
            make_table(visited_cap), hi0[None], lo0[None],
            jnp.ones((1,), bool), jnp.zeros((1,), jnp.int32))
        vhi, vlo, vpay = table.slots_hi, table.slots_lo, table.slot_payload
    else:
        vhi = jnp.full((visited_cap,), SENTINEL, jnp.uint32).at[0].set(hi0)
        vlo = jnp.full((visited_cap,), SENTINEL, jnp.uint32).at[0].set(lo0)
        vpay = jnp.zeros((0,), jnp.int32)
    archive = jnp.zeros((visited_cap, m), jnp.int32).at[0].set(c0)
    false = jnp.asarray(False)
    return ExploreState(
        frontier=frontier, frontier_n=jnp.asarray(1, jnp.int32),
        visited_hi=vhi, visited_lo=vlo, visited_payload=vpay,
        visited_n=jnp.asarray(1, jnp.int32),
        archive=archive, archive_n=jnp.asarray(1, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
        branch_overflow=false, frontier_overflow=false, visited_overflow=false,
    )


def _sort_dedup_verdict(state: ExploreState, hi, lo, cand_valid, V: int):
    """Historical sort-based dedup: visited entries and candidates in one
    keyspace, one ``lax.sort`` per wave — ``O((V+K)·log(V+K))``.  Returns
    the per-candidate new-mask (first occurrence of an unseen hash)."""
    K = hi.shape[0]
    all_hi = jnp.concatenate([state.visited_hi, hi])
    all_lo = jnp.concatenate([state.visited_lo, lo])
    # candidates carry their index as payload; visited carry K (dropped).
    payload = jnp.concatenate(
        [jnp.full((V,), K, jnp.int32), jnp.arange(K, dtype=jnp.int32)]
    )
    is_cand = jnp.concatenate(
        [jnp.zeros((V,), jnp.int32), cand_valid.astype(jnp.int32)]
    )
    # Keys: (hi, lo, 1-is_cand ... ) — visited first within equal hashes so a
    # candidate equal to a visited entry sees eq_prev=True.  Sorting
    # (hi, lo, ~cand) keeps visited (0) ahead of candidates (1).
    s_hi, s_lo, s_cand, s_payload = jax.lax.sort(
        (all_hi, all_lo, is_cand, payload), num_keys=3
    )
    eq_prev = jnp.concatenate([
        jnp.zeros((1,), bool),
        (s_hi[1:] == s_hi[:-1]) & (s_lo[1:] == s_lo[:-1]),
    ])
    new_sorted = (s_cand == 1) & ~eq_prev
    # scatter back to candidate order (payload == K for visited -> dropped)
    return jnp.zeros((K,), bool).at[s_payload].set(new_sorted, mode="drop")


def _explore_step(state: ExploreState, comp: CompiledAny,
                  max_branches: int, backend,
                  dedup: str = "hash") -> ExploreState:
    """One BFS level: expand, hash, dedup, compact.  Traceable; the body of
    the on-device while_loop in :func:`_explore_loop`.

    ``dedup="hash"`` (default) resolves the wave against the
    device-resident open-addressing table in ``O(K·probe)`` gathers —
    lookup (no writes), intra-wave first-occurrence on a scratch table,
    then insertion of only the ``n_ins`` selected candidates, so excess
    discoveries beyond the frontier cap are *not* marked visited and
    regenerate later, exactly like the sorted path.  ``dedup="sort"``
    keeps the historical full-sort (the bench baseline).  Both produce
    bit-identical archives outside the visited-overflow regime (where the
    drop *policy* differs: sorted merge drops the largest hashes, the
    table drops probe-bound losers — both sound, both flagged)."""
    F, m = state.frontier.shape
    V = state.archive.shape[0]
    T = max_branches

    live = jnp.arange(F) < state.frontier_n
    out = backend.expand(state.frontier, comp, T)

    cand = out.configs.reshape(F * T, m)
    cand_valid = (out.valid & live[:, None]).reshape(F * T)
    branch_ovf = jnp.any(out.overflow & live)

    hi, lo = config_hash(cand)
    hi = jnp.where(cand_valid, hi, SENTINEL)
    lo = jnp.where(cand_valid, lo, SENTINEL)

    probe_ovf = jnp.asarray(False)
    if dedup == "hash":
        table = HashTable(state.visited_hi, state.visited_lo,
                          state.visited_payload, state.visited_n)
        found, _ = lookup(table, hi, lo, cand_valid)
        first, ovf_f = first_occurrence(hi, lo, cand_valid)
        new_mask = cand_valid & first & ~found
        probe_ovf = ovf_f
    else:
        new_mask = _sort_dedup_verdict(state, hi, lo, cand_valid, V)

    n_new = jnp.sum(new_mask, dtype=jnp.int32)
    # new candidates first (stable), then everything else
    order = jnp.argsort(jnp.logical_not(new_mask), stable=True)
    n_ins = jnp.minimum(n_new, F)  # only these become frontier AND visited
    take = jnp.arange(F)
    sel = order[:F]
    next_frontier = cand[sel]
    ins_mask = take < n_ins

    if dedup == "hash":
        # --- table insert of the selected prefix only (payload = archive row)
        table, _, ovf_i = insert_unique(
            table, hi[sel], lo[sel], ins_mask,
            (state.archive_n + take).astype(jnp.int32))
        probe_ovf = probe_ovf | ovf_i
        m_hi, m_lo, m_pay = table.slots_hi, table.slots_lo, table.slot_payload
        visited_n = table.count
        visited_ovf = (state.visited_overflow | probe_ovf
                       | (state.visited_n + n_ins > V))
    else:
        # --- visited merge (entries beyond capacity fall off the sorted tail)
        ins_hi = jnp.where(ins_mask, hi[sel], SENTINEL)
        ins_lo = jnp.where(ins_mask, lo[sel], SENTINEL)
        m_hi, m_lo = jax.lax.sort(
            (jnp.concatenate([state.visited_hi, ins_hi]),
             jnp.concatenate([state.visited_lo, ins_lo])),
            num_keys=2,
        )
        m_hi, m_lo = m_hi[:V], m_lo[:V]
        m_pay = state.visited_payload
        visited_n = jnp.minimum(state.visited_n + n_ins, V)
        visited_ovf = state.visited_overflow | (state.visited_n + n_ins > V)

    # --- archive append in discovery order
    arch_idx = jnp.where(ins_mask, state.archive_n + take, V)
    archive = state.archive.at[arch_idx].set(next_frontier, mode="drop")
    archive_n = jnp.minimum(state.archive_n + n_ins, V)

    return ExploreState(
        frontier=next_frontier,
        frontier_n=n_ins,
        visited_hi=m_hi, visited_lo=m_lo, visited_payload=m_pay,
        visited_n=visited_n,
        archive=archive, archive_n=archive_n,
        step=state.step + 1,
        branch_overflow=state.branch_overflow | branch_ovf,
        frontier_overflow=state.frontier_overflow | (n_new > F),
        visited_overflow=visited_ovf,
    )


@functools.partial(
    jax.jit, static_argnames=("max_steps", "max_branches", "backend", "dedup"))
def _explore_loop(state: ExploreState, comp: CompiledAny, max_steps: int,
                  max_branches: int, backend,
                  dedup: str = "hash") -> ExploreState:
    """Entire BFS as one on-device ``lax.while_loop``: runs until the
    frontier drains or ``max_steps`` levels, with zero host round-trips."""

    def cond(s: ExploreState):
        return (s.step < max_steps) & (s.frontier_n > 0)

    def body(s: ExploreState):
        return _explore_step(s, comp, max_branches, backend, dedup)

    return jax.lax.while_loop(cond, body, state)


def _explore_chunked(comp, be, state: ExploreState, *, max_steps: int,
                     max_branches: int, checkpoint_dir: Optional[str],
                     checkpoint_every: int, fault_injector,
                     dedup: str = "hash") -> ExploreState:
    """Drive :func:`_explore_loop` with optional checkpoint/resume.

    Without a ``checkpoint_dir`` this is the historical single
    ``_explore_loop`` call.  With one, the BFS runs in chunks of
    ``checkpoint_every`` levels, snapshotting the full
    :class:`ExploreState` (frontier, visited hashes, archive, overflow
    flags) via the atomic-rename checkpoint machinery between device
    loops; on entry the latest complete snapshot is restored.  The loop
    condition uses the *absolute* step, so chunked runs are bit-identical
    to an uninterrupted one, and a run killed mid-chunk resumes from its
    last snapshot and re-executes only that chunk (recovery by
    re-execution — free by determinism).  ``fault_injector`` (a
    :class:`repro.runtime.faults.FaultInjector`) is consulted before
    every device loop, so tests can kill any chunk deterministically.
    """
    if checkpoint_dir is None:
        if fault_injector is not None:
            fault_injector.on_device_call()
        return _explore_loop(state, comp, max_steps, max_branches, be, dedup)
    from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                             save_checkpoint)
    if checkpoint_every < 1:
        raise ValueError("checkpoint_every must be >= 1")
    if latest_step(checkpoint_dir) is not None:
        host = jax.tree.map(np.asarray, state)
        restored, _, _ = restore_checkpoint(checkpoint_dir, host)
        state = ExploreState(*(jnp.asarray(x) for x in restored))
    while True:
        step, fn = (int(x) for x in
                    jax.device_get((state.step, state.frontier_n)))
        if not (step < max_steps and fn > 0):
            return state
        if fault_injector is not None:
            fault_injector.on_device_call()
        bound = min(max_steps, step + checkpoint_every)
        state = _explore_loop(state, comp, bound, max_branches, be, dedup)
        save_checkpoint(checkpoint_dir, int(state.step),
                        jax.tree.map(np.asarray, state))


def resolve_dedup(dedup: str, *, frontier_cap: int, visited_cap: int,
                  max_branches: int) -> str:
    """Resolve ``"auto"`` to a concrete dedup scheme for this workload
    shape (both schemes produce bit-identical archives outside
    visited-overflow, so this only moves wall-time).

    The sorted path re-sorts the full capacity-``V`` archive beside the
    wave every level — its cost grows with ``visited_cap`` even when few
    configurations are visited — while the hash table's probe loops cost
    roughly a flat per-wave amount on top of ``O(K·probe)`` work
    (``K = frontier_cap · max_branches``).  Measured on CPU the table
    overtakes the sort once the visited capacity clears ~16k entries and
    dominates the wave (EXPERIMENTS.md §Explore); below that the sort's
    three fused ops beat the table's dispatch-bound probe loops."""
    if dedup == "auto":
        wave = frontier_cap * max_branches
        return "hash" if visited_cap >= max(16384, 8 * wave) else "sort"
    if dedup not in ("hash", "sort"):
        raise ValueError(f"unknown dedup mode {dedup!r}")
    return dedup


def explore(
    system: SNPSystem | CompiledAny,
    *,
    max_steps: int = 64,
    frontier_cap: int = 256,
    visited_cap: int = 4096,
    max_branches: int = 64,
    init: Optional[Sequence[int]] = None,
    backend: Optional[BackendLike] = None,
    plan: Optional[SystemPlan] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 32,
    fault_injector=None,
    dedup: str = "auto",
) -> ExploreResult:
    """BFS-explore the computation tree (paper Algorithm 1).

    Stops when the frontier drains (both paper stopping criteria are
    subsumed: dead configs — including the zero vector — produce no
    successors, and already-seen configs are never re-inserted) or after
    ``max_steps`` levels.  The loop is a single device-side
    ``lax.while_loop``; the host sees only the final state.

    ``backend`` selects the transition implementation (``"ref"``,
    ``"pallas"``, ``"sparse"``, ``"sparse_pallas"``, or any registered
    :class:`~repro.core.backend.StepBackend` instance); an ``SNPSystem`` is
    lowered by the backend's own ``compile``; the archive is identical
    across backends.  ``backend=None`` (the default) hands the choice to
    the query planner: the default ``SystemPlan(mode="auto")`` picks the
    fastest known backend/encoding/block configuration for this workload
    shape (autotune cache → cost model → heuristic — DESIGN.md §3
    "Planner & autotuner"); pre-compiled inputs keep their historical
    backend (``"ref"`` dense, ``"sparse"`` for sparse encodings).

    ``plan`` (:class:`~repro.core.plan.SystemPlan`) tunes the storage
    layout the backend lowers to (e.g. ``encoding="hybrid"`` for
    heavy-tailed graphs) and the planning mode; the default plan is
    bit-identical to passing none (all backends agree on valid entries).

    ``checkpoint_dir`` enables checkpoint/resume: the BFS snapshots its
    full device state every ``checkpoint_every`` levels (atomic rename,
    content-verified — :mod:`repro.checkpoint`) and restores the latest
    snapshot on entry, so a killed run re-invoked with the same arguments
    — e.g. under :func:`repro.runtime.faults.run_supervised` — resumes
    bit-identically instead of starting over.  The capacities must match
    the checkpointed run's (a mismatch raises at restore).
    ``fault_injector`` deterministically kills scheduled device loops for
    tests and the fault bench tier.

    A planner-picked backend (``backend=None`` auto path) that fails at
    compile, lower, or run time degrades down the encoding-compatible
    chain (:mod:`repro.core.failover`) with a warning — a backend the
    caller *named* raises instead.

    ``dedup`` selects the visited-set structure: ``"hash"`` keeps a
    device-resident open-addressing table — ``O(K·probe)`` per wave
    regardless of visited size — while ``"sort"`` is the historical
    full-sort path, ``O((V+K)·log(V+K))`` per wave (kept as the bench
    baseline and a differential-testing oracle).  ``"auto"`` (default)
    applies :func:`resolve_dedup`: the sort's per-wave cost scales with
    the visited *capacity* while the table's is roughly flat, so the
    table wins once ``visited_cap`` dominates the wave size
    ``frontier_cap · max_branches`` (measured crossover — EXPERIMENTS.md
    §Explore) and the sort keeps small/wave-dominated workloads.
    Archives are bit-identical between the two outside visited-overflow
    (see :func:`_explore_step`).
    """
    dedup = resolve_dedup(dedup, frontier_cap=frontier_cap,
                          visited_cap=visited_cap, max_branches=max_branches)
    # Branch work per step is bounded by frontier_cap × max_branches.
    be, plan, planned = resolve_entry_info(
        system, backend, plan, workload=(frontier_cap, max_branches))
    if plan is not None and plan.num_shards > 1:
        _resolve_comp(system, be, plan)   # caller error: raise, don't degrade
    init_arr = None if init is None else jnp.asarray(init, jnp.int32)

    def attempt(be, plan):
        comp = _resolve_comp(system, be, plan)
        state = _init_state(comp, frontier_cap, visited_cap, init_arr, dedup)
        return _explore_chunked(
            comp, be, state, max_steps=max_steps, max_branches=max_branches,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            fault_injector=fault_injector, dedup=dedup)

    state = run_with_failover(attempt, be, plan, degradable=planned)
    # single host sync: one explicit device_get of the final state (the
    # explicit form keeps the whole call legal under a d2h transfer guard)
    arch, n, fn, step, b_ovf, f_ovf, v_ovf = jax.device_get(
        (state.archive, state.archive_n, state.frontier_n, state.step,
         state.branch_overflow, state.frontier_overflow,
         state.visited_overflow))
    n = int(n)
    ovf = (bool(b_ovf), bool(f_ovf), bool(v_ovf))
    return ExploreResult(
        configs=arch[:n],
        num_discovered=n,
        steps=int(step),
        exhausted=int(fn) == 0 and not any(ovf),
        branch_overflow=ovf[0],
        frontier_overflow=ovf[1],
        visited_overflow=ovf[2],
    )


# ---------------------------------------------------------------------------
# Small-system utilities (host-driven, used by tests & the paper repro)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_branches", "backend"))
def _succ_one(config, comp, max_branches, backend):
    out = backend.expand(config, comp, max_branches)
    return out.configs, out.valid, out.emissions, out.overflow


def successor_set(
    system: SNPSystem | CompiledAny, config: Sequence[int],
    max_branches: int = 64, backend: BackendLike = "ref",
    plan: Optional[SystemPlan] = None,
) -> List[Tuple[Tuple[int, ...], int]]:
    """Distinct (successor, emission) pairs of one configuration."""
    be = get_backend(backend)
    comp = _resolve_comp(system, be, plan)
    c = jnp.asarray(config, jnp.int32)
    cfgs, valid, emis, ovf = _succ_one(c, comp, max_branches, be)
    if bool(ovf):
        raise ValueError("branch overflow; raise max_branches")
    seen, out = set(), []
    for i in np.nonzero(np.asarray(valid))[0]:
        key = (tuple(int(v) for v in cfgs[i]), int(emis[i]))
        if key not in seen:
            seen.add(key)
            out.append(key)
    return out


def emission_gaps(
    comp: SNPSystem | CompiledAny, *, max_time: int, max_gap: int,
    max_branches: int = 64, backend: BackendLike = "ref",
) -> set[int]:
    """All gaps between the first two environment emissions, over every
    computation path of length <= ``max_time``.

    The number computed by an SNP generator is exactly this gap (paper §2.1);
    for the paper's Π in exact mode the result must be {2, 3, ...} ∩ bound.
    BFS over *augmented* states (config, elapsed-since-first-emission) keeps
    the search polynomial even though the path count is exponential.
    """
    comp = comp if is_compiled(comp) else get_backend(backend).compile(comp)
    # phase A: no emission yet; phase B: (config, elapsed) since 1st emission
    init = tuple(int(v) for v in np.asarray(comp.init_config))
    phase_a: set = {init}
    phase_b: set = set()
    gaps: set[int] = set()
    for _ in range(max_time):
        new_a: set = set()
        new_b: set = set()
        for cfg in phase_a:
            for nxt, emis in successor_set(comp, cfg, max_branches, backend):
                if emis > 0:
                    new_b.add((nxt, 0))
                else:
                    new_a.add(nxt)
        for cfg, elapsed in phase_b:
            if elapsed + 1 > max_gap:
                continue
            for nxt, emis in successor_set(comp, cfg, max_branches, backend):
                if emis > 0:
                    gaps.add(elapsed + 1)
                else:
                    new_b.add((nxt, elapsed + 1))
        phase_a, phase_b = new_a, new_b
        if not phase_a and not phase_b:
            break
    return gaps


# ---------------------------------------------------------------------------
# Trace serving: the batched scan and its single-path wrapper.  The batched
# path (`run_traces`) is the serving primitive; `run_trace` is a B=1 view of
# it, and `core.distributed.run_traces_distributed` shards its batch axis
# over a mesh (both bit-identical by per-trace PRNG keys).
# ---------------------------------------------------------------------------


class TraceOut(NamedTuple):
    """:func:`run_traces` output — a NamedTuple, so both field access and
    4-way unpacking work.  ``branch_overflow[b, t]`` flags that trace b
    had more than ``max_branches`` successors at step t (only the first T
    were candidates): truncated branching is reported, never silent.  The
    serving layer surfaces it as ``TraceResult.branch_overflow`` and a
    service counter."""

    configs: jnp.ndarray          # (B, steps, m) int32
    emissions: jnp.ndarray        # (B, steps) int32
    alive: jnp.ndarray            # (B, steps) bool
    branch_overflow: jnp.ndarray  # (B, steps) bool


@functools.partial(
    jax.jit, static_argnames=("steps", "max_branches", "policy", "backend"))
def _traces_scan(comp, c0s, keys, steps, max_branches, policy, backend):
    """B independent trajectories, one ``lax.scan`` over time.

    ``c0s`` (B, m), ``keys`` (B, 2) — per-trace PRNG streams, split exactly
    as the single-trace path splits its key, so trace b depends only on
    ``keys[b]`` and batching never changes a trajectory.
    """
    B = c0s.shape[0]

    def body(carry, _):
        cfgs, keys = carry
        out = backend.expand(cfgs, comp, max_branches)     # (B, T, m)
        n_valid = jnp.sum(out.valid, axis=-1, dtype=jnp.int32)  # (B,)
        if policy == "random":
            pair = jax.vmap(jax.random.split)(keys)        # (B, 2, 2)
            keys, subs = pair[:, 0], pair[:, 1]
            idx = jax.vmap(
                lambda k, n: jax.random.randint(k, (), 0, jnp.maximum(n, 1))
            )(subs, n_valid)
        else:
            idx = jnp.zeros((B,), jnp.int32)
        has = n_valid > 0
        pick = jnp.take_along_axis(
            out.configs, idx[:, None, None], axis=1)[:, 0]  # (B, m)
        nxt = jnp.where(has[:, None], pick, cfgs)
        emis = jnp.where(
            has, jnp.take_along_axis(out.emissions, idx[:, None], axis=1)[:, 0],
            0)
        ovf = out.overflow & has
        return (nxt, keys), (nxt, emis, has, ovf)

    (_, _), (cfgs, emis, alive, ovf) = jax.lax.scan(
        body, (c0s, keys), None, length=steps)
    # scan stacks time first: (steps, B, ...) -> (B, steps, ...)
    return TraceOut(jnp.swapaxes(cfgs, 0, 1), jnp.swapaxes(emis, 0, 1),
                    jnp.swapaxes(alive, 0, 1), jnp.swapaxes(ovf, 0, 1))


def run_traces(
    system: SNPSystem | CompiledAny, *, steps: int,
    seeds: Sequence[int] | np.ndarray | jnp.ndarray,
    policy: str = "first", max_branches: int = 64,
    backend: Optional[BackendLike] = None,
    plan: Optional[SystemPlan] = None,
):
    """Batched trajectory serving: B independent paths in one jitted scan.

    Returns a :class:`TraceOut` — ``(configs (B, steps, m), emissions
    (B, steps), alive (B, steps), branch_overflow (B, steps))`` with
    ``B = len(seeds)``.  Row b is bit-identical to
    ``run_trace(..., seed=seeds[b])`` with the same policy/backend — the
    batch dimension rides through the backend's ``expand`` (one transition
    per step for the whole batch), which is the serving-path hot loop.
    ``backend=None`` (the default) hands the choice to the query planner
    under the default ``SystemPlan(mode="auto")`` — see :func:`explore`;
    traces are backend-independent, so the planner only moves wall-time,
    and a failing planner pick degrades down the chain
    (:mod:`repro.core.failover`) instead of raising.
    """
    if policy not in ("first", "random"):
        raise ValueError(f"unknown policy {policy!r}")
    seeds = jnp.asarray(seeds, jnp.uint32)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be 1-D, got shape {seeds.shape}")
    be, plan, planned = resolve_entry_info(
        system, backend, plan, workload=(int(seeds.shape[0]), max_branches))
    if plan is not None and plan.num_shards > 1:
        _resolve_comp(system, be, plan)   # caller error: raise, don't degrade
    keys = jax.vmap(jax.random.PRNGKey)(seeds)             # (B, 2)

    def attempt(be, plan):
        comp = _resolve_comp(system, be, plan)
        c0s = jnp.broadcast_to(comp.init_config, (seeds.shape[0],) +
                               comp.init_config.shape)
        out = _traces_scan(comp, c0s, keys, steps, max_branches, policy, be)
        jax.block_until_ready(out.configs)   # first-run failures degrade too
        return out

    return run_with_failover(attempt, be, plan, degradable=planned)


def run_trace(
    system: SNPSystem | CompiledAny, *, steps: int,
    policy: str = "first", seed: int = 0, max_branches: int = 64,
    backend: Optional[BackendLike] = None,
    plan: Optional[SystemPlan] = None,
):
    """Single-path simulation (deterministic or uniformly random branch).

    Returns a :class:`TraceOut` of (configs (steps, m), emissions
    (steps,), alive (steps,), branch_overflow (steps,)).
    The 'serving' mode of the engine: one trajectory, spike train out.
    Implemented as a B=1 :func:`run_traces` batch, so the single- and
    batched-serving paths can never drift apart.
    """
    out = run_traces(
        system, steps=steps, seeds=[seed], policy=policy,
        max_branches=max_branches, backend=backend, plan=plan)
    return TraceOut(*(x[0] for x in out))
