"""Pluggable step backends: one transition API behind every consumer.

The paper's contribution is that the SNP transition is a single
device-friendly primitive ``C' = C + S·M_Π`` (eq. 2).  Historically each
consumer (``engine.explore``, ``core.distributed``, ``run_trace``) called
the pure-jnp reference semantics directly, so alternative implementations
of the same primitive — the fused Pallas kernel today, a sparse/CSR
backend next (Hernández-Tello et al. 2024) — had no way into any real
workload.  This module is that seam:

* :class:`StepBackend` — the protocol: ``expand(configs, comp,
  max_branches) -> StepOut`` plus capability/padding metadata.  ``expand``
  must be pure and traceable (consumers call it inside ``jit``,
  ``lax.while_loop``, ``lax.scan`` and ``shard_map``), and all registered
  backends must agree bit-for-bit on the *valid* entries of
  :class:`~repro.core.semantics.StepOut` for spike counts < 2^24.
* :class:`RefBackend` (``"ref"``) — the pure-jnp oracle
  (:func:`~repro.core.semantics.next_configs`).
* :class:`PallasBackend` (``"pallas"``) — the fused TPU kernel
  (:func:`repro.kernels.snp_step.ops.snp_step`); interpret mode on CPU,
  ``interpret=False`` on real TPUs.  Does not materialize the spiking
  vectors, so ``StepOut.spiking`` is ``None``.
* :class:`SparseBackend` (``"sparse"``) — gather/segment-sum over the
  ELL/segment encoding (:class:`~repro.core.matrix.CompiledSparseSNP`);
  ``O(B·T·m·degree)`` work and memory, the scaling path for large systems
  (Hernández-Tello et al. 2024).
* :class:`SparsePallasBackend` (``"sparse_pallas"``) — the fused Pallas
  kernel over the same sparse encoding
  (:func:`repro.kernels.snp_step.sparse_ops.snp_step_sparse`).
* a name registry — :func:`register_backend` / :func:`get_backend` /
  :func:`available_backends` — so new backends land as plugins without
  touching the consumers.

Each backend also owns its *compilation*, driven by the **lowering
registry** (DESIGN.md §3 "Kernel lowering"): every backend declares
``supported_encodings()`` — the :class:`~repro.core.plan.SystemPlan`
encodings its step can realize, first entry = its native layout — and a
``lower(compiled, plan)`` hook that annotates a built encoding with
whatever its kernel consumes (e.g. ``PallasBackend`` attaches the dense
per-shard operands to a :class:`~repro.core.plan.ShardedCompiled`).
``backend.compile(system, plan=...)`` is then one shared template:
resolve the plan's encoding against the registry, build it through the
shared compilers (dense :class:`~repro.core.matrix.CompiledSNP`, ELL /
hybrid :class:`~repro.core.matrix.CompiledSparseSNP`, neuron-axis
:class:`~repro.core.plan.ShardedCompiled`), and hand it to ``lower``.
The **default plan is bit-identical** to each backend's historical
encoding, and a plan a backend cannot honor is a ``ValueError``, never a
silent reinterpretation or downgrade.  Consumers resolve backends by name
and call ``compile`` once, so a new encoding lights up every workload
with no consumer changes — and plan choice is orthogonal to backend
choice across the whole matrix.

Backends are frozen dataclasses: hashable, so they ride through
``jax.jit(..., static_argnames=("backend",))`` unchanged.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Protocol, Tuple, Union, runtime_checkable

import jax.numpy as jnp

from .matrix import (CompiledAny, CompiledSNP, CompiledSparseSNP,
                     compile_system, compile_system_sparse, is_delayed)
from .plan import (KernelConfig, ShardedCompiled, SystemPlan,
                   compile_sharded, is_sharded, lower_shard_dense)
from .semantics import (StepOut, delayed_next_configs, next_configs,
                        sparse_delayed_next_configs, sparse_next_configs)
from .system import SNPSystem

__all__ = [
    "StepBackend",
    "RefBackend",
    "PallasBackend",
    "SparseBackend",
    "SparsePallasBackend",
    "register_backend",
    "get_backend",
    "available_backends",
    "compile_with_plan",
    "lower_with_backend",
    "resolve_entry",
    "resolve_entry_info",
    "resolve_kernel",
    "supported_under",
    "supports_sharded",
]


@runtime_checkable
class StepBackend(Protocol):
    """One synchronous SNP transition step, pluggable per workload.

    Implementations must be hashable (frozen dataclasses) so consumers can
    pass them as static jit arguments, and ``expand`` must be traceable.

    Capability / padding metadata:

    * ``name``              — registry name (``backend="<name>"`` end-to-end).
    * ``supports_nd_batch`` — ``expand`` accepts arbitrary leading batch
      dims ``(..., m)``; backends that flatten internally still set True.
    * ``pad_multiple``      — batch sizes are padded internally to a
      multiple of this (1 = no padding); callers sizing frontiers/batches
      can round to it to avoid wasted lanes.
    * ``materializes_spiking`` — whether ``StepOut.spiking`` is populated
      (``None`` otherwise).
    """

    name: str
    supports_nd_batch: bool
    pad_multiple: int
    materializes_spiking: bool

    def compile(self, system: SNPSystem,
                plan: Optional[SystemPlan] = None) -> CompiledAny:
        """Lower ``system`` to the encoding this backend's ``expand``
        consumes.  The contract every implementation must honor:

        * **host-side, not traceable** — runs numpy/Python freely; never
          called inside ``jit``/``scan``/``shard_map``.
        * **returns a compiled encoding** — an object for which
          :func:`repro.core.matrix.is_compiled` is True, and whose arrays
          form a jax pytree (consumers pass it through ``jit`` and
          ``shard_map`` as data, replicated ``P()`` on meshes).
        * **deterministic** — structurally equal systems (``SNPSystem`` is
          a frozen dataclass) must lower to semantically identical
          encodings.  Consumers rely on this to memoize: every entry point
          compiles at most once per call, and
          :class:`~repro.serve.snp_service.SNPTraceService` keeps a
          FIFO-bounded ``{system: compiled}`` cache keyed by structural
          equality, so ``compile`` may be arbitrarily expensive but must
          not be stateful.
        * **owns the encoding choice** — dense vs. sparse is invisible to
          consumers; ``expand`` must reject a foreign encoding with
          ``TypeError`` (see ``_require_sparse``) rather than
          mis-interpret it.  Pre-compiled objects passed by callers skip
          ``compile`` entirely, so the check lives in ``expand``.
        * **honors the plan or refuses it** — ``plan=None`` (or the
          default :class:`~repro.core.plan.SystemPlan`) must produce the
          backend's historical encoding **bit-identically**; an encoding
          request the backend cannot realize (``supported_encodings``)
          raises ``ValueError``; ``plan.num_shards > 1`` lowers through
          :func:`repro.core.plan.compile_sharded` where ``"sharded"`` is
          supported and raises elsewhere.
        """
        ...

    def supported_encodings(self,
                            semantics: str = "no_delays"
                            ) -> Tuple[str, ...]:
        """Plan encodings this backend's lowering can realize *under the
        given semantics tier* — a subset of ``("dense", "ell", "hybrid",
        "sharded")``, **first entry = the native layout**
        ``encoding="auto"`` resolves to.  ``"sharded"`` additionally marks
        that the backend's step can consume one shard of a
        :class:`~repro.core.plan.ShardedCompiled` inside
        ``explore_distributed``.  An empty tuple means the backend cannot
        run that semantics at all; the built-ins all run
        ``semantics="delays"`` single-device but none shard it yet, so
        a sharded delays plan raises (never a silent downgrade)."""
        ...

    def lower(self, compiled: "CompiledLike",
              plan: SystemPlan) -> "CompiledLike":
        """Annotate a built encoding with whatever this backend's step
        consumes (host-side, deterministic, idempotent — same contract as
        ``compile``, whose template calls it last).  Also invoked by
        consumers on *pre-compiled* objects, so a backend can refuse an
        encoding its kernel cannot lower (``ValueError``) instead of
        silently downgrading at expand time.  The default is identity."""
        ...

    def expand(self, configs: jnp.ndarray, comp: CompiledAny,
               max_branches: int) -> StepOut:
        """All successors of ``configs`` (..., m): a :class:`StepOut` with
        ``configs`` (..., T, m), ``valid``/``emissions`` (..., T) and
        ``overflow`` (...,)."""
        ...


CompiledLike = Union[CompiledAny, ShardedCompiled]


def _require_sparse(comp, backend_name: str) -> CompiledSparseSNP:
    if not isinstance(comp, CompiledSparseSNP):
        raise TypeError(
            f"backend {backend_name!r} needs a CompiledSparseSNP "
            "(use compile_system_sparse / backend.compile), got "
            f"{type(comp).__name__}")
    return comp


def _plan_or_default(plan: Optional[SystemPlan]) -> SystemPlan:
    return SystemPlan() if plan is None else plan


def supported_under(backend: "StepBackend", semantics: str
                    ) -> Tuple[str, ...]:
    """``backend.supported_encodings`` under a semantics tier, tolerating
    third-party backends that predate the semantics parameter: those keep
    answering for ``no_delays`` and are declared incapable (empty tuple)
    of anything else."""
    sup_fn = getattr(backend, "supported_encodings", None)
    if sup_fn is None:
        return ()
    try:
        return sup_fn(semantics=semantics)
    except TypeError:
        return sup_fn() if semantics == "no_delays" else ()


def _registry_compile(backend: "StepBackend", system: SNPSystem,
                      plan: Optional[SystemPlan]) -> CompiledLike:
    """The shared ``compile`` template every registered backend delegates
    to: resolve the plan's encoding against ``supported_encodings()``
    under the plan's semantics tier, build it through the shared
    compilers, hand it to ``lower``."""
    plan = _plan_or_default(plan)
    sup = backend.supported_encodings(semantics=plan.semantics)
    if plan.num_shards > 1:
        # Sharded plans lower to per-shard ELL encodings for every
        # backend (DESIGN.md §2); compile_sharded owns the encoding
        # validation there (it refuses hybrid/dense), so only the
        # 'sharded' capability is the backend's to declare.
        if "sharded" not in sup:
            raise ValueError(
                f"backend {backend.name!r} cannot realize a neuron-axis "
                f"sharded plan under semantics={plan.semantics!r} "
                f"(supported encodings: {sup}); pick a backend whose "
                "lowering supports 'sharded' there")
        return backend.lower(compile_sharded(system, plan), plan)
    enc = sup[0] if plan.encoding == "auto" else plan.encoding
    if enc not in sup:
        raise ValueError(
            f"backend {backend.name!r} cannot realize plan encoding "
            f"{plan.encoding!r} under semantics={plan.semantics!r} "
            f"(supported: {sup}); pick a matching backend or drop the "
            "plan")
    if enc == "dense":
        built = compile_system(system, semantics=plan.semantics)
    else:
        built = compile_system_sparse(
            system, hub_threshold=plan.resolved_hub_threshold(system),
            semantics=plan.semantics)
    return backend.lower(built, plan)


def compile_with_plan(backend: "StepBackend", system: SNPSystem,
                      plan: Optional[SystemPlan]) -> CompiledAny:
    """``backend.compile`` with an optional plan, tolerating third-party
    backends that predate the plan parameter (they only ever see the
    default plan, which is the identity — the entry points always carry a
    plan now, so the identity check matters, not just ``None``)."""
    if plan is None or plan == SystemPlan():
        return backend.compile(system)
    return backend.compile(system, plan=plan)


def lower_with_backend(backend: "StepBackend", compiled: CompiledLike,
                       plan: Optional[SystemPlan]) -> CompiledLike:
    """``backend.lower`` on a pre-compiled encoding, tolerating
    third-party backends that predate the lowering registry (identity)."""
    lower = getattr(backend, "lower", None)
    if lower is None:
        return compiled
    return lower(compiled, _plan_or_default(plan))


def _check_kernel_plan(backend: "StepBackend", plan: SystemPlan) -> None:
    """Lower-time validation of ``plan.kernel`` against the backend it
    landed on — a block shape a backend cannot honor is a ``ValueError``
    with a real message, never a silently ignored field."""
    cfg = plan.kernel
    if cfg is None:
        return
    if not hasattr(backend, "block_b"):
        raise ValueError(
            f"backend {backend.name!r} has no kernel block parameters; "
            f"drop SystemPlan.kernel={cfg} or pick a Pallas-kernel "
            "backend ('pallas', 'sparse_pallas')")
    if cfg.block_n is not None and not hasattr(backend, "block_n"):
        raise ValueError(
            f"plan kernel sets block_n={cfg.block_n}, but backend "
            f"{backend.name!r} keeps the whole neuron axis resident per "
            "block (no rule-axis tiling); drop block_n — only the dense "
            "'pallas' lowering tiles that axis")


def resolve_kernel(backend: "StepBackend",
                   plan: Optional[SystemPlan]) -> "StepBackend":
    """Fold ``plan.kernel`` into ``backend``: a new (frozen, hashable)
    instance carrying the plan's block shape, so every downstream cache
    keyed on the backend — jit static args, ``distributed``'s lru-cached
    shard functions — keys on the block configuration automatically.
    Identity when the plan carries no kernel config; ``ValueError`` when
    the backend cannot honor it (:func:`_check_kernel_plan`).  The
    per-axis ``None`` fields keep the backend's own defaults, so the same
    compiled encoding re-lowers at different block shapes without
    rebuilding."""
    plan = _plan_or_default(plan)
    cfg = plan.kernel
    if cfg is None:
        return backend
    _check_kernel_plan(backend, plan)
    fields = {f: v for f in ("block_b", "block_t", "block_n")
              if (v := getattr(cfg, f)) is not None and hasattr(backend, f)}
    return dataclasses.replace(backend, **fields) if fields else backend


def resolve_entry_info(system, backend: Optional["BackendLike"],
                       plan: Optional[SystemPlan], *,
                       workload: Optional[Tuple[int, int]] = None,
                       ) -> Tuple["StepBackend", SystemPlan, bool]:
    """:func:`resolve_entry` plus *who chose*: the third element is True
    exactly when the query planner picked the backend (so a failure may
    gracefully degrade down :data:`repro.core.failover.DEGRADE_ORDER`)
    and False when the caller pinned it by name or plan (pinning is a
    contract — a pinned backend's failure raises)."""
    plan = _plan_or_default(plan)
    planned = False
    if backend is None:
        if (plan.backend is None and plan.mode in ("auto", "measure")
                and plan.encoding == "auto" and plan.kernel is None
                and isinstance(system, SNPSystem)):
            plan = SystemPlan.for_system(
                system, num_shards=plan.num_shards, workload=workload,
                mode=plan.mode, semantics=plan.semantics)
            planned = True
        name = plan.backend
        if name is None:
            name = "sparse" if isinstance(system, CompiledSparseSNP) \
                else "ref"
            planned = False
        be = get_backend(name)
    else:
        be = get_backend(backend)
    return resolve_kernel(be, plan), plan, planned


def resolve_entry(system, backend: Optional["BackendLike"],
                  plan: Optional[SystemPlan], *,
                  workload: Optional[Tuple[int, int]] = None,
                  ) -> Tuple["StepBackend", SystemPlan]:
    """Shared backend/plan resolution for the engine entry points
    (``explore``/``run_traces`` and the distributed pair).

    When the caller names no backend and leaves the plan open
    (``mode="auto"|"measure"``, no pinned backend/encoding/kernel), the
    query planner decides: ``SystemPlan.for_system`` consults the
    autotune cache, then the analytic cost model, then the static degree
    heuristic (DESIGN.md §3 "Planner & autotuner"), with ``workload=(B,
    T)`` the batch/branch shape the entry point is about to run.  A named
    backend, a pinned plan, or ``mode="static"`` bypasses planning and
    preserves the historical behavior (``"ref"`` for raw systems and
    dense/sharded compileds, ``"sparse"`` for sparse ones).  Either way
    the plan's kernel config is folded into the returned backend
    (:func:`resolve_kernel`)."""
    be, plan, _ = resolve_entry_info(system, backend, plan,
                                     workload=workload)
    return be, plan


def supports_sharded(backend: "StepBackend") -> bool:
    """Whether the backend may serve a neuron-axis-sharded run
    (registry-declared; third-party backends without the registry hooks
    default to no).  The built-in kernel backends step each shard through
    their own fused kernels; any other backend declaring ``"sharded"`` is
    served by the jnp sparse shard math, which every registered backend
    must match bit-for-bit anyway (see the ``expand`` contract)."""
    sup = getattr(backend, "supported_encodings", None)
    return sup is not None and "sharded" in sup()


@dataclass(frozen=True)
class RefBackend:
    """Pure-jnp reference semantics (the repo's oracle).  Under a sharded
    plan, ``explore_distributed`` runs the jnp sparse math on each shard's
    slice (DESIGN.md §2)."""

    name: str = "ref"
    supports_nd_batch: bool = True
    pad_multiple: int = 1
    materializes_spiking: bool = True

    def supported_encodings(self,
                            semantics: str = "no_delays"
                            ) -> Tuple[str, ...]:
        # Delays run single-device only: the halo exchange has no notion
        # of countdown/pending yet, so sharded delays must raise.
        return ("dense",) if semantics == "delays" else ("dense", "sharded")

    def lower(self, compiled: CompiledLike, plan: SystemPlan) -> CompiledLike:
        _check_kernel_plan(self, plan)  # no kernel: plan.kernel is an error
        return compiled

    def compile(self, system: SNPSystem,
                plan: Optional[SystemPlan] = None) -> CompiledLike:
        return _registry_compile(self, system, plan)

    def expand(self, configs: jnp.ndarray, comp: CompiledSNP,
               max_branches: int) -> StepOut:
        if is_delayed(comp):
            return delayed_next_configs(configs, comp, max_branches)
        return next_configs(configs, comp, max_branches)


@dataclass(frozen=True)
class PallasBackend:
    """Fused Pallas transition kernel (decode + S·M + C in VMEM).

    ``interpret=True`` (default) emulates the kernel with jittable lax ops
    so the same code path runs on CPU; flip to False on a real TPU.  Block
    shapes are clamped to the problem size by the ops wrapper, so the
    defaults are safe for small systems too.  Under a sharded plan,
    ``lower`` attaches the dense per-shard operands
    (:func:`repro.core.plan.lower_shard_dense`) and the same kernel body
    consumes one shard per device: ``C' = C + halo·H_adj + S·M_local``.
    """

    name: str = "pallas"
    interpret: bool = True
    block_b: int = 8
    block_t: int = 32
    block_n: int = 128
    supports_nd_batch: bool = True   # flattens leading dims internally
    materializes_spiking: bool = False

    @property
    def pad_multiple(self) -> int:
        return self.block_b

    @property
    def kernel_config(self) -> KernelConfig:
        """This instance's block shape as a plan-carriable config."""
        return KernelConfig(block_b=self.block_b, block_t=self.block_t,
                            block_n=self.block_n)

    def with_kernel(self, kernel: KernelConfig) -> "PallasBackend":
        """A re-blocked instance (``None`` fields keep this one's)."""
        return resolve_kernel(self, SystemPlan(kernel=kernel))

    def supported_encodings(self,
                            semantics: str = "no_delays"
                            ) -> Tuple[str, ...]:
        return ("dense",) if semantics == "delays" else ("dense", "sharded")

    def lower(self, compiled: CompiledLike, plan: SystemPlan) -> CompiledLike:
        _check_kernel_plan(self, plan)
        if is_sharded(compiled):
            return lower_shard_dense(compiled)
        return compiled

    def compile(self, system: SNPSystem,
                plan: Optional[SystemPlan] = None) -> CompiledLike:
        return _registry_compile(self, system, plan)

    def expand(self, configs: jnp.ndarray, comp: CompiledSNP,
               max_branches: int) -> StepOut:
        # Lazy import: keeps repro.core importable if the Pallas toolchain
        # is absent, and avoids a core <-> kernels import cycle at load.
        from repro.kernels.snp_step.ops import snp_step

        w = configs.shape[-1]  # m, or 3m under delayed semantics
        batch = configs.shape[:-1]
        flat = configs.reshape(-1, w)
        out, valid, emis, overflow = snp_step(
            flat, comp, max_branches=max_branches,
            block_b=self.block_b, block_t=self.block_t,
            block_n=self.block_n, interpret=self.interpret,
        )
        T = max_branches
        return StepOut(
            configs=out.reshape(*batch, T, w),
            valid=valid.reshape(*batch, T),
            emissions=emis.reshape(*batch, T),
            overflow=overflow.reshape(batch),
            spiking=None,
        )


@dataclass(frozen=True)
class SparseBackend:
    """Gather/segment-sum step over the ELL/segment encoding.

    Replaces the dense ``S·M`` einsum with (1) per-neuron mixed-radix
    decode, (2) a selection-table lookup of the fired rule per neuron, and
    (3) a ``K_in``-wide gather over the synapse in-adjacency — never
    materializing the ``(B, T, n)`` one-hot spiking tensor or the dense
    ``(n, m)`` matrix.  Work and memory scale with ``nnz(M_Π)``
    (``O(B·T·m·degree)``) instead of ``O(B·T·n·m)``; valid entries are
    bit-identical to ``"ref"`` for spike counts < 2^24.
    """

    name: str = "sparse"
    supports_nd_batch: bool = True
    pad_multiple: int = 1
    materializes_spiking: bool = False

    def supported_encodings(self,
                            semantics: str = "no_delays"
                            ) -> Tuple[str, ...]:
        return ("ell", "hybrid") if semantics == "delays" \
            else ("ell", "hybrid", "sharded")

    def lower(self, compiled: CompiledLike, plan: SystemPlan) -> CompiledLike:
        _check_kernel_plan(self, plan)  # no kernel: plan.kernel is an error
        return compiled

    def compile(self, system: SNPSystem,
                plan: Optional[SystemPlan] = None
                ) -> Union[CompiledSparseSNP, ShardedCompiled]:
        return _registry_compile(self, system, plan)

    def expand(self, configs: jnp.ndarray, comp: CompiledSparseSNP,
               max_branches: int) -> StepOut:
        comp = _require_sparse(comp, self.name)
        if is_delayed(comp):
            return sparse_delayed_next_configs(configs, comp, max_branches)
        return sparse_next_configs(configs, comp, max_branches)


@dataclass(frozen=True)
class SparsePallasBackend:
    """Fused Pallas kernel over the sparse encoding (decode + selection
    lookup + in-adjacency gather in VMEM), for pure-ELL **and** hybrid
    ELL+COO plans — the COO tail runs as an in-kernel scatter-free
    segment-sum stage over the compiler's ``coo_bounds``/``hub_slot``
    metadata (DESIGN.md §3 "Kernel lowering").  Under a sharded plan the
    same body consumes one shard per device through the extended
    ``[local | halo | zero]`` index space.

    ``interpret=True`` (default) emulates the kernel on CPU; the grid is
    ``(B/bb, T/bt)`` with the whole neuron axis resident per block, so the
    working set is ``O(bb·bt·m)`` — the ops wrapper clamps blocks to the
    problem size.  TPU story scales with nnz, not ``n·m``.
    """

    name: str = "sparse_pallas"
    interpret: bool = True
    block_b: int = 8
    block_t: int = 32
    supports_nd_batch: bool = True   # flattens leading dims internally
    materializes_spiking: bool = False

    @property
    def pad_multiple(self) -> int:
        return self.block_b

    @property
    def kernel_config(self) -> KernelConfig:
        """This instance's block shape as a plan-carriable config (no
        ``block_n`` — the neuron axis is never tiled)."""
        return KernelConfig(block_b=self.block_b, block_t=self.block_t)

    def with_kernel(self, kernel: KernelConfig) -> "SparsePallasBackend":
        """A re-blocked instance (``None`` fields keep this one's)."""
        return resolve_kernel(self, SystemPlan(kernel=kernel))

    def supported_encodings(self,
                            semantics: str = "no_delays"
                            ) -> Tuple[str, ...]:
        return ("ell", "hybrid") if semantics == "delays" \
            else ("ell", "hybrid", "sharded")

    def lower(self, compiled: CompiledLike, plan: SystemPlan) -> CompiledLike:
        _check_kernel_plan(self, plan)
        # A hybrid encoding the kernel cannot lower must raise here, at
        # lowering time — never a silent downgrade to the jnp path.  Only
        # hand-built encodings can trip this: compile_system_sparse always
        # emits the COO segment metadata.
        if isinstance(compiled, CompiledSparseSNP) and compiled.is_hybrid \
                and (compiled.coo_bounds is None
                     or compiled.hub_slot is None):
            raise ValueError(
                "sparse_pallas cannot lower this hybrid ELL+COO encoding: "
                "it lacks the COO segment metadata (coo_bounds/hub_slot) "
                "the fused kernel's segment-sum stage consumes; lower the "
                "system through compile_system_sparse / backend.compile")
        return compiled

    def compile(self, system: SNPSystem,
                plan: Optional[SystemPlan] = None
                ) -> Union[CompiledSparseSNP, ShardedCompiled]:
        return _registry_compile(self, system, plan)

    def expand(self, configs: jnp.ndarray, comp: CompiledSparseSNP,
               max_branches: int) -> StepOut:
        from repro.kernels.snp_step.sparse_ops import snp_step_sparse

        comp = self.lower(_require_sparse(comp, self.name),
                          SystemPlan.default())
        w = configs.shape[-1]  # m, or 3m under delayed semantics
        batch = configs.shape[:-1]
        flat = configs.reshape(-1, w)
        out, valid, emis, overflow = snp_step_sparse(
            flat, comp, max_branches=max_branches,
            block_b=self.block_b, block_t=self.block_t,
            interpret=self.interpret,
        )
        T = max_branches
        return StepOut(
            configs=out.reshape(*batch, T, w),
            valid=valid.reshape(*batch, T),
            emissions=emis.reshape(*batch, T),
            overflow=overflow.reshape(batch),
            spiking=None,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, StepBackend] = {}

BackendLike = Union[str, StepBackend]


def register_backend(backend: StepBackend, *, overwrite: bool = False) -> None:
    """Register ``backend`` under ``backend.name``.

    Later backends (sparse/CSR, multi-kernel, TPU-native) plug in here; the
    consumers (`explore`, `run_trace(s)`, `explore_distributed`,
    `snp_service`, benchmarks) pick them up by name with zero changes.
    """
    if not overwrite and backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: BackendLike) -> StepBackend:
    """Resolve a backend by registry name (or pass an instance through).

    Instances are duck-checked against the *pre-registry* core of the
    protocol (``name`` + ``expand``) rather than the full
    :class:`StepBackend`, so third-party backends that predate the
    lowering registry hooks keep resolving — the tolerant
    :func:`lower_with_backend` / :func:`supports_sharded` helpers cover
    the missing methods downstream."""
    if isinstance(name, str):
        try:
            return _REGISTRY[name]
        except KeyError:
            raise ValueError(
                f"unknown step backend {name!r}; "
                f"available: {available_backends()}"
            ) from None
    if hasattr(name, "expand") and hasattr(name, "name"):
        return name
    raise TypeError(f"expected backend name or StepBackend, got {type(name)}")


register_backend(RefBackend())
register_backend(PallasBackend())
register_backend(SparseBackend())
register_backend(SparsePallasBackend())
