"""Graceful backend degradation: walk an explicit, encoding-compatible
fallback chain when a planner-picked backend fails — never silently,
never crash-looping.

The query planner (PR 6, :mod:`repro.core.autotune`) picks a backend from
cost estimates, so its pick can be *wrong in kind*, not just in speed: a
Pallas toolchain missing at import, a kernel that fails to lower a shape,
an interpret-mode path that only breaks at first run.  When — and only
when — the planner made the choice (``backend=None`` auto entry points),
the engine walks :data:`DEGRADE_ORDER` restricted to backends whose
lowering registry (PR 5, ``StepBackend.supported_encodings``) can realize
the plan's encoding, warns once per edge, and notifies listeners (the
serving layer counts degradations in its stats).  A caller who *named* a
backend gets the failure raised — pinning is a contract, not a hint.

``plan.kernel`` never survives degradation: block configs are tied to the
backend the autotuner measured them on, and ``_check_kernel_plan`` would
(correctly) refuse them on a non-Pallas fallback.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from dataclasses import dataclass
from typing import Callable, List, Tuple

from repro.runtime.faults import InjectedFault

from .plan import SystemPlan

__all__ = ["DEGRADE_ORDER", "DegradeEvent", "degrade_candidates",
           "run_with_failover", "record_degradation",
           "add_degrade_listener", "remove_degrade_listener"]

# Most-specialized first; every chain walk moves strictly rightward, so a
# degraded run can never bounce back to the backend that just failed.
DEGRADE_ORDER: Tuple[str, ...] = ("sparse_pallas", "pallas", "sparse", "ref")


@dataclass(frozen=True)
class DegradeEvent:
    """One degradation edge: which backend failed, at what stage
    (``"compile"``, ``"lower"``, ``"run"``, ``"serve"``), falling back to
    what, and the failure's repr."""

    from_backend: str
    to_backend: str
    stage: str
    error: str


_LOCK = threading.Lock()
_WARNED: set = set()
_LISTENERS: List[Callable[[DegradeEvent], None]] = []


def add_degrade_listener(cb: Callable[[DegradeEvent], None]) -> None:
    """Register a callback invoked on every degradation (used by the
    serving layer to count degradations in service stats)."""
    with _LOCK:
        _LISTENERS.append(cb)


def remove_degrade_listener(cb: Callable[[DegradeEvent], None]) -> None:
    with _LOCK:
        if cb in _LISTENERS:
            _LISTENERS.remove(cb)


def record_degradation(from_backend: str, to_backend: str, stage: str,
                       error: BaseException) -> DegradeEvent:
    """Emit one degradation: warn once per (from, to) edge for the
    process lifetime, always notify listeners.  Never silent."""
    event = DegradeEvent(from_backend, to_backend, stage, repr(error))
    with _LOCK:
        first = (from_backend, to_backend) not in _WARNED
        _WARNED.add((from_backend, to_backend))
        listeners = list(_LISTENERS)
    if first:
        warnings.warn(
            f"backend {from_backend!r} failed at {stage} time "
            f"({event.error}); degrading to {to_backend!r} — results are "
            "bit-identical across backends, only speed changes "
            "(DESIGN.md §4.4)", RuntimeWarning, stacklevel=3)
    for cb in listeners:
        cb(event)
    return event


def degrade_candidates(backend, plan: SystemPlan
                       ) -> List[Tuple[object, SystemPlan]]:
    """Encoding-compatible fallbacks strictly after ``backend`` in
    :data:`DEGRADE_ORDER`, each paired with the plan it should run under
    (same encoding choice, ``kernel`` stripped, backend re-pinned).

    A candidate must be able to realize the plan's *resolved* encoding —
    a degraded run re-lowers the same plan, so e.g. ``sparse_pallas``
    (ell/hybrid) degrades to ``sparse``, never to the dense-only ``ref``;
    a sharded plan only degrades to sharded-capable backends.
    """
    from .backend import get_backend  # late: backend.py is upstream of us
    name = getattr(backend, "name", None)
    if name not in DEGRADE_ORDER:
        return []
    out: List[Tuple[object, SystemPlan]] = []
    semantics = getattr(plan, "semantics", "no_delays")
    for cand_name in DEGRADE_ORDER[DEGRADE_ORDER.index(name) + 1:]:
        cand = get_backend(cand_name)
        sup = cand.supported_encodings(semantics=semantics)
        if not sup:
            continue
        if plan.num_shards > 1 and "sharded" not in sup:
            continue
        if plan.encoding != "auto" and plan.encoding not in sup:
            continue
        out.append((cand, dataclasses.replace(
            plan, backend=cand_name, kernel=None)))
    return out


def run_with_failover(attempt: Callable[[object, SystemPlan], object],
                      backend, plan: SystemPlan, *, degradable: bool,
                      stage: str = "run"):
    """Run ``attempt(backend, plan)``; when ``degradable`` (the planner
    picked the backend), walk the degrade chain on failure.

    ``attempt`` must cover compile + lower + first run, so a backend that
    only breaks on its first device call still degrades.  Injected faults
    (:class:`repro.runtime.faults.InjectedFault`) are *not* degraded —
    they model node/device loss, whose recovery path is the supervisor's
    checkpoint-resume, not a backend swap.  The last failure re-raises
    when the chain is exhausted.
    """
    if not degradable:
        return attempt(backend, plan)
    chain = [(backend, plan)] + degrade_candidates(backend, plan)
    last: BaseException = None
    for i, (be, p) in enumerate(chain):
        try:
            return attempt(be, p)
        except InjectedFault:
            raise
        except Exception as e:
            last = e
            if i + 1 < len(chain):
                record_degradation(be.name, chain[i + 1][0].name, stage, e)
    raise last
