"""Multi-chip SNP workloads (shard_map): tree exploration + trace serving.

Two entry points share the mesh plumbing:

* :func:`explore_distributed` — hash-partitioned BFS over the computation
  tree (frontier and visited set sharded by config hash);
* :func:`run_traces_distributed` — data-parallel batched trajectory
  serving: the batch axis of :func:`repro.core.engine.run_traces` sharded
  over the mesh, bit-identical to the single-device path (DESIGN.md §4).

The paper runs on one GPU; at fleet scale both the frontier and the visited
set must shard.  The exploration scheme (DESIGN.md §2):

* **hash ownership** — configuration with hash ``h`` is owned by device
  ``h mod n_dev``.  Ownership decides (a) which visited-shard a config is
  deduped against and (b) which frontier-shard expands it.  Uniform hashing
  doubles as load balancing: each BFS level spreads across chips in
  expectation regardless of tree shape.
* **expand locally, exchange by owner** — each device expands its frontier
  shard through the same pluggable :class:`~repro.core.backend.StepBackend`
  as the single-chip engine (``backend="ref"`` or ``"pallas"``; the fused
  kernel on TPU), bins successors by owner, and exchanges them with one tiled
  ``all_to_all``.  Received candidates are deduped against the *local*
  visited shard only — no global synchronization beyond the one collective.
* **static capacities** — per-destination send slots, frontier and visited
  shards are fixed-size; every overflow is detected and psum-reported.
  Dropped candidates are simply *not marked visited*, so they are
  regenerated and explored later: soundness is preserved (same argument as
  the single-chip engine).

For **large m** (the ROADMAP's ``m >= 10^5`` regime) the dense-row
exchange above stops scaling: every shipped candidate costs ``O(m)``.
Passing a :class:`~repro.core.plan.SystemPlan` with ``num_shards == ndev``
flips ``explore_distributed`` into the **neuron-axis-sharded** scheme
(DESIGN.md §2): the frontier, archive and every candidate carry only their
``mloc = ceil(m/ndev)`` neuron slice per device; expansion steps the local
slice through the selected backend — the jnp sparse math or a fused
Pallas kernel consuming the shard's extended-index encoding (DESIGN.md §3
"Kernel lowering") — and exchanges only the *touched segments*: the fired
produce of halo neurons along synapses that cross a shard boundary, a
static ``O(cut)`` payload per step instead of ``O(m)`` rows.  The batch-hash ownership scheme stays: global config hashes are
recovered from additive per-slice partials
(:func:`~repro.core.hashing.zobrist_hash` + one ``psum``) and each device
still dedups exactly the candidates it hash-owns against its local
visited shard.

The per-step program is one jit(shard_map(...)) over a 1-D device axis —
on the production mesh this is the flattened ``(pod, data, model)`` axes
(SNP exploration is pure data parallelism; the model axes contribute their
devices to the frontier partition).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                  # jax >= 0.6 exposes it at top level
    from jax import shard_map
except ImportError:                   # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map

from .backend import (BackendLike, PallasBackend, SparsePallasBackend,
                      compile_with_plan, lower_with_backend, resolve_entry,
                      resolve_entry_info, supports_sharded)
from .engine import ExploreResult, TraceOut, _traces_scan
from .failover import run_with_failover
from .hashing import SENTINEL, config_hash, zobrist_hash
from .hashtable import (HashTable, _base_slot, _canonical, first_occurrence,
                        insert_unique, lookup, table_slots)
from .matrix import CompiledAny, is_compiled
from .plan import (DenseShardArrays, ShardArrays, ShardedCompiled,
                   SystemPlan, compile_sharded, is_sharded, shard_view)
from .semantics import (_decode_digits, _fired_packed, packed_rule_table,
                        sparse_branch_info)
from .system import SNPSystem

__all__ = ["explore_distributed", "run_traces_distributed"]


# ---------------------------------------------------------------------------
# Checkpoint/resume for the fused device loops.  Both exploration schemes
# run their BFS as one ``lax.while_loop`` under shard_map; the absolute
# step and the convergence scalar ride the carry, so chunking the loop on
# absolute step bounds (``checkpoint_every`` levels per device call) is
# bit-identical to an uninterrupted run.  The state tuple is snapshotted
# between chunks through the atomic-rename machinery and a re-invoked run
# restores the latest snapshot (re-sharded onto the live mesh via each
# template leaf's sharding) and continues bit-identically.
# ---------------------------------------------------------------------------


def _restore_loop_state(checkpoint_dir, state: tuple):
    """(state, start_step): the latest snapshot re-device_put with the
    live state's shardings, or the fresh state at step 0."""
    from repro.checkpoint.checkpoint import latest_step, restore_checkpoint
    if checkpoint_dir is None:
        return state, 0
    last = latest_step(checkpoint_dir)
    if last is None:
        return state, 0
    host = jax.tree.map(np.asarray, tuple(state))
    restored, step, _ = restore_checkpoint(checkpoint_dir, host, step=last)
    put = tuple(jax.device_put(arr, ref.sharding)
                for arr, ref in zip(restored, state))
    return put, step


def _save_loop_state(checkpoint_dir, step: int, state: tuple) -> None:
    from repro.checkpoint.checkpoint import save_checkpoint
    save_checkpoint(checkpoint_dir, step, jax.tree.map(np.asarray,
                                                       tuple(state)))


def _run_fused_loop(loop_fn, lead, state, *, max_steps, checkpoint_dir,
                    checkpoint_every, fault_injector):
    """Drive a fused BFS while-loop to convergence.

    Without checkpointing this is ONE device call covering all
    ``max_steps`` levels: the convergence poll is the while-loop predicate
    on device, so no host transfer happens between BFS levels.  With
    ``checkpoint_dir`` the same executable is called per chunk
    (``checkpoint_every`` absolute levels each; ``bound`` is a traced
    scalar) — bit-identical to the uninterrupted run, with only the two
    loop scalars read back between chunks.  ``state`` is the loop carry
    with ``step`` at ``[-2]`` and the convergence count at ``[-1]``."""
    if checkpoint_dir is None:
        if fault_injector is not None:
            fault_injector.on_device_call()
        return loop_fn(*lead, *state, jnp.asarray(max_steps, jnp.int32))
    state, _ = _restore_loop_state(checkpoint_dir, state)
    step, total_new = (int(x) for x in jax.device_get(
        (state[-2], state[-1])))
    while step < max_steps and total_new > 0:
        bound = min(step + checkpoint_every, max_steps)
        if fault_injector is not None:
            fault_injector.on_device_call()
        state = loop_fn(*lead, *state, jnp.asarray(bound, jnp.int32))
        step, total_new = (int(x) for x in jax.device_get(
            (state[-2], state[-1])))
        if step < max_steps and total_new > 0:
            _save_loop_state(checkpoint_dir, step, state)
    return state


def _flat_mesh(mesh: Optional[Mesh]) -> Tuple[Mesh, str]:
    """Resolve ``mesh`` to a 1-D mesh + axis name, flattening N-d meshes
    (SNP serving and exploration are pure data parallelism, so every mesh
    axis contributes its devices to the one batch/frontier axis)."""
    if mesh is None:
        return Mesh(np.array(jax.devices()), ("x",)), "x"
    if len(mesh.axis_names) == 1:
        return mesh, mesh.axis_names[0]
    return Mesh(mesh.devices.reshape(-1), ("x",)), "x"


def _dense_body(comp, carry, *, axis, ndev, max_branches, send_cap,
                visited_cap, backend):
    """One BFS level of the dense-row scheme (runs inside the fused
    ``lax.while_loop`` under shard_map over ``axis``).  ``ndev`` is the
    static mesh size (it sizes bincounts and send buffers); dedup is the
    per-device hash-table shard (``core.hashtable``), so a level costs
    ``O(R·probe)`` gathers instead of re-sorting the visited shard."""
    (frontier, frontier_valid, vhi, vlo, vpay, vcount, archive, archive_n,
     flags, step, _) = carry
    F, m = frontier.shape
    T = max_branches
    K = F * T
    C = send_cap

    # --- expand local frontier -------------------------------------------
    out = backend.expand(frontier, comp, T)
    cand = out.configs.reshape(K, m)
    valid = (out.valid & frontier_valid[:, None]).reshape(K)
    branch_ovf = jnp.any(out.overflow & frontier_valid)

    # --- bin successors by hash owner and exchange ------------------------
    hi, lo = config_hash(cand)
    owner = jnp.where(valid, (hi % np.uint32(ndev)).astype(jnp.int32), ndev)
    order = jnp.argsort(owner, stable=True)
    owner_sorted = owner[order]
    counts = jnp.bincount(jnp.minimum(owner, ndev), length=ndev + 1)[:ndev]
    group_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(K, dtype=jnp.int32) - jnp.where(
        owner_sorted < ndev, group_start[jnp.minimum(owner_sorted, ndev - 1)], 0)
    send_ovf = jnp.any(counts > C)
    slot = jnp.where(
        (owner_sorted < ndev) & (pos < C),
        owner_sorted * C + pos,
        ndev * C,  # dropped
    )
    send_cfg = jnp.zeros((ndev * C, m), jnp.int32).at[slot].set(
        cand[order], mode="drop")
    send_val = jnp.zeros((ndev * C,), jnp.int32).at[slot].set(
        (owner_sorted < ndev).astype(jnp.int32), mode="drop")
    # ship the (8-byte) hashes with the payload: rehashing the received
    # candidates costs ~m*4 bytes of elementwise traffic per config, the
    # wire cost of sending them is negligible (§Perf cell C)
    send_hi = jnp.zeros((ndev * C,), jnp.uint32).at[slot].set(
        hi[order], mode="drop")
    send_lo = jnp.zeros((ndev * C,), jnp.uint32).at[slot].set(
        lo[order], mode="drop")

    recv_cfg = jax.lax.all_to_all(send_cfg, axis, 0, 0, tiled=True)
    recv_val = jax.lax.all_to_all(send_val, axis, 0, 0, tiled=True)
    rhi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
    rlo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)

    # --- dedup received candidates against the local table shard ----------
    rvalid = recv_val == 1
    table = HashTable(vhi, vlo, vpay, vcount[0])
    found, _ = lookup(table, rhi, rlo, rvalid)
    first, ovf_f = first_occurrence(rhi, rlo, rvalid)
    new_mask = rvalid & first & ~found

    n_new = jnp.sum(new_mask, dtype=jnp.int32)
    sel = jnp.argsort(~new_mask, stable=True)[:F]
    n_ins = jnp.minimum(n_new, F)
    ins = jnp.arange(F) < n_ins
    next_frontier = recv_cfg[sel]
    frontier_ovf = n_new > F

    # only the selected prefix becomes visited (payload = archive row), so
    # excess discoveries regenerate later — same soundness as the engine
    table, _, ovf_i = insert_unique(
        table, rhi[sel], rlo[sel], ins,
        (archive_n + jnp.arange(F)).astype(jnp.int32))
    visited_ovf = ovf_f | ovf_i | (vcount[0] + n_ins > visited_cap)

    arch_idx = jnp.where(ins, archive_n + jnp.arange(F), archive.shape[0])
    archive = archive.at[arch_idx].set(next_frontier, mode="drop")
    archive_n = jnp.minimum(archive_n + n_ins, archive.shape[0])

    flags = flags | jnp.stack([branch_ovf | send_ovf, frontier_ovf,
                               visited_ovf])
    total_new = jax.lax.psum(n_ins, axis)
    return (next_frontier, ins, table.slots_hi, table.slots_lo,
            table.slot_payload, table.count[None], archive, archive_n,
            flags, step + 1, total_new)


def _dense_loop(comp, frontier, fvalid, vhi, vlo, vpay, vcount, archive,
                archive_n, flags, step, total_new, bound, *, axis, ndev,
                max_branches, send_cap, visited_cap, backend):
    """The whole dense-row BFS (up to ``bound`` absolute levels) as one
    ``lax.while_loop`` under shard_map: the historical host-side
    ``int(total_new) == 0`` poll is now the loop predicate on the
    psum-replicated convergence scalar, so the run performs **zero host
    transfers** between BFS levels.  ``bound`` is a traced replicated
    scalar — chunked (checkpointing) calls reuse one executable."""
    carry = (frontier, fvalid, vhi, vlo, vpay, vcount, archive, archive_n,
             flags, step, total_new)

    def cond(c):
        return (c[-2] < bound) & (c[-1] > 0)

    def body(c):
        return _dense_body(comp, c, axis=axis, ndev=ndev,
                           max_branches=max_branches, send_cap=send_cap,
                           visited_cap=visited_cap, backend=backend)

    return jax.lax.while_loop(cond, body, carry)


# ---------------------------------------------------------------------------
# Neuron-axis sharded exploration (SystemPlan.num_shards == ndev)
# ---------------------------------------------------------------------------


def _psum_u32(x, axis):
    """psum for uint32 lanes: wraparound int32 all-reduce, bitcast back."""
    s = jax.lax.psum(jax.lax.bitcast_convert_type(x, jnp.int32), axis)
    return jax.lax.bitcast_convert_type(s, jnp.uint32)


def _sharded_body(arrs: ShardArrays, dense, carry, *, axis, ndev,
                  mloc, hmax, max_branches, visited_cap, backend):
    """Per-device body of the neuron-axis-sharded BFS level.

    Device ``d`` holds only the ``(F, mloc)`` neuron slice of the
    (replicated-membership) frontier; all *bookkeeping* (validity, branch
    counts, dedup verdicts, selection) is computed identically on every
    device from psum/all_gather-combined scalars, so the devices stay in
    lockstep without any O(m) exchange:

    1. local branch info on the slice; the mixed-radix strides cross shard
       boundaries, so each local stride is multiplied by the product of
       the *downstream* shards' branch totals (one ``all_gather`` of ndev
       scalars per config);
    2. fired produce/consume per local neuron; the halo exchange ships
       only the produce values along boundary-crossing synapses (static
       ``send_idx`` metadata from the plan) with one tiled ``all_to_all``;
    3. candidate slices = local slice + local delta, through the
       ``backend``'s step: the jnp sparse math (``ref``/``sparse``) or a
       fused kernel consuming the extended [local | halo] encoding
       (``pallas``/``sparse_pallas`` — DESIGN.md §3 "Kernel lowering");
       the collective stays out here, so kernel bodies hold no
       collectives and the halo values are backend-independent;
    4. global hashes from additive per-slice partials (one psum) — the
       zobrist positions are the shard's ``global_idx`` column map, so a
       degree-permuted partition hashes identically to a contiguous one;
       each device dedups the candidates it hash-owns against its local
       hash-table shard and the verdicts are psum-combined;
    5. every device appends the same selected candidates (its slice of
       them) to its archive shard.
    """
    (frontier, fvalid, vhi, vlo, vpay, vcount, archive, archive_n, flags,
     step, _) = carry
    F = frontier.shape[0]
    T = max_branches
    K = F * T
    V = visited_cap
    A = archive.shape[0]
    S = ndev
    idx = jax.lax.axis_index(axis)
    view = shard_view(arrs)

    # --- local branch info + cross-shard radix combine --------------------
    info = sparse_branch_info(frontier, view)
    tots = jax.lax.all_gather(info.psi, axis)                # (S, F)
    after = (jnp.arange(S) > idx)[:, None]
    below = jnp.prod(jnp.where(after, tots, 1.0), axis=0)    # (F,)
    psi = jnp.prod(tots, axis=0)                             # (F,) replicated
    stride = info.stride * below[:, None]
    alive = jax.lax.psum(
        jnp.any(info.app, axis=-1).astype(jnp.int32), axis) > 0

    t = jnp.arange(T, dtype=jnp.int32)

    # Dispatch on the concrete built-in kernel backends (their block/
    # interpret knobs are part of the contract here); any other backend
    # declaring 'sharded' — including third-party registrations — is
    # served by the jnp sparse math below, which every registered backend
    # must match bit-for-bit anyway (backend.py contract).
    if isinstance(backend, (PallasBackend, SparsePallasBackend)):
        # Kernel path: decode the fired produce only at the (static) send
        # positions — same f32 math on the same values as the full decode,
        # so the halo payload is bit-identical to the jnp path — exchange
        # it, then run the whole expansion inside the fused kernel.
        from repro.kernels.snp_step.ops import snp_step_dense_shard
        from repro.kernels.snp_step.sparse_ops import snp_step_sparse_shard

        send_ids = arrs.send_idx[0].reshape(-1)              # (S·hmax,)
        smask = send_ids < mloc
        sid = jnp.minimum(send_ids, mloc - 1)
        if isinstance(backend, SparsePallasBackend):
            # the sparse kernel consumes the whole table anyway
            tab = packed_rule_table(info, view)              # (F, mloc, R)
            tab_s = jnp.take(tab, sid, axis=1)               # (F, SH, R)
        else:
            # the dense kernel works from rank/app/M_local — build the
            # packed table only at the send positions (a subset view of
            # the per-neuron segments yields the same math per neuron)
            tab_s = packed_rule_table(
                info, view._replace(seg_start=view.seg_start[sid],
                                    seg_count=view.seg_count[sid]))
        sub = info._replace(stride=jnp.take(stride, sid, axis=-1),
                            choices=jnp.take(info.choices, sid, axis=-1))
        digits_s = _decode_digits(t, sub)                    # (F, T, SH)
        packed_s = _fired_packed(digits_s, tab_s)
        prod_send = jnp.where(smask[None, None, :], packed_s & 0xFFFF, 0)
        halo = jax.lax.all_to_all(
            prod_send.reshape(F, T, S, hmax), axis, 2, 2,
            tiled=True).reshape(F, T, S * hmax)
        if isinstance(backend, SparsePallasBackend):
            out = snp_step_sparse_shard(
                frontier, stride, info.choices, psi, tab, arrs.in_idx[0],
                halo, max_branches=T, block_b=backend.block_b,
                block_t=backend.block_t, interpret=backend.interpret)
        else:
            out = snp_step_dense_shard(
                frontier, info.rank, info.app, stride, info.choices, psi,
                dense.onehot[0], dense.M_local[0], dense.hadj[0], halo,
                max_branches=T, block_b=backend.block_b,
                block_t=backend.block_t, block_n=backend.block_n,
                interpret=backend.interpret)
        cand = out.reshape(K, mloc)
    else:
        # jnp path ("ref"/"sparse"): fired actions on the whole slice,
        # halo send gathered from the full produce table.
        tab = packed_rule_table(info, view)                  # (F, mloc, R)
        digits = _decode_digits(t, info._replace(stride=stride))
        packed_f = _fired_packed(digits, tab)                # (F, T, mloc)
        prod_f = packed_f & 0xFFFF
        cons_f = packed_f >> 16

        prod_pad = jnp.concatenate(
            [prod_f, jnp.zeros((F, T, 1), jnp.int32)], axis=-1)
        send = jnp.take(prod_pad, arrs.send_idx[0].reshape(-1), axis=-1)
        recv = jax.lax.all_to_all(
            send.reshape(F, T, S, hmax), axis, 2, 2, tiled=True)
        prod_ext = jnp.concatenate(
            [prod_f, recv.reshape(F, T, S * hmax),
             jnp.zeros((F, T, 1), jnp.int32)], axis=-1)
        delta = -cons_f
        in_idx = arrs.in_idx[0]
        for k in range(in_idx.shape[1]):  # static K_in, unrolled
            delta = delta + jnp.take(prod_ext, in_idx[:, k], axis=-1)
        cand = (frontier[:, None, :] + delta).reshape(K, mloc)
    valid = ((t[None, :].astype(jnp.float32) < psi[:, None])
             & alive[:, None] & fvalid[:, None]).reshape(K)
    branch_ovf = jnp.any((psi > float(T)) & fvalid)

    # --- global hashes from additive slice partials -----------------------
    hi, lo = zobrist_hash(cand, positions=arrs.global_idx[0])
    hi = jnp.where(valid, _psum_u32(hi, axis), SENTINEL)
    lo = jnp.where(valid, _psum_u32(lo, axis), SENTINEL)

    # --- dedup: each device judges the candidates it hash-owns against
    # its local table shard; verdicts psum-combine to the global new-mask
    owner = jnp.where(valid, (hi % np.uint32(S)).astype(jnp.int32), S)
    mine = owner == idx
    table = HashTable(vhi, vlo, vpay, vcount[0])
    found, _ = lookup(table, hi, lo, mine)
    first, ovf_f = first_occurrence(hi, lo, mine)
    new_local = mine & first & ~found
    new_mask = jax.lax.psum(new_local.astype(jnp.int32), axis) > 0

    # --- replicated selection + per-device state updates ------------------
    n_new = jnp.sum(new_mask, dtype=jnp.int32)
    order = jnp.argsort(jnp.logical_not(new_mask), stable=True)
    sel = order[:F]
    n_ins = jnp.minimum(n_new, F)
    ins = jnp.arange(F) < n_ins
    next_frontier = cand[sel]

    sel_mine = mine[sel] & ins
    n_mine = jnp.sum(sel_mine, dtype=jnp.int32)
    table, _, ovf_i = insert_unique(
        table, hi[sel], lo[sel], sel_mine,
        (archive_n + jnp.arange(F)).astype(jnp.int32))
    visited_ovf = ovf_f | ovf_i | ((vcount[0] + n_mine) > V)

    arch_idx = jnp.where(ins, archive_n + jnp.arange(F), A)
    archive = archive.at[arch_idx].set(next_frontier, mode="drop")
    archive_n = jnp.minimum(archive_n + n_ins, A)

    flags = flags | jnp.stack([branch_ovf, n_new > F, visited_ovf])[None, :]
    # n_ins is already the replicated global count (selection is replicated)
    return (next_frontier, ins, table.slots_hi, table.slots_lo,
            table.slot_payload, table.count[None], archive, archive_n,
            flags, step + 1, n_ins)


def _sharded_loop(arrs, dense, carry, bound, **kw):
    """Fused neuron-sharded BFS: one ``lax.while_loop`` over levels with
    the psum-replicated new-config count as the convergence predicate —
    zero host transfers until the frontier drains or ``bound`` absolute
    levels (same contract as :func:`_dense_loop`)."""

    def cond(c):
        return (c[-2] < bound) & (c[-1] > 0)

    def body(c):
        return _sharded_body(arrs, dense, c, **kw)

    return jax.lax.while_loop(cond, body, carry)


def _sharded_loop_dense(arrs, dense, *args, **kw):
    *state, bound = args
    return _sharded_loop(arrs, dense, tuple(state), bound, **kw)


def _sharded_loop_nodense(arrs, *args, **kw):
    *state, bound = args
    return _sharded_loop(arrs, None, tuple(state), bound, **kw)


def _explore_neuron_sharded(
    comp: ShardedCompiled, mesh: Mesh, axis: str, backend, *,
    max_steps: int, frontier_cap: int, visited_cap: int, max_branches: int,
    init: Optional[Sequence[int]] = None,
    checkpoint_dir: Optional[str] = None, checkpoint_every: int = 32,
    fault_injector=None,
) -> ExploreResult:
    """Host driver for the neuron-axis-sharded BFS.  ``frontier_cap`` is
    the *global* frontier width (its membership bookkeeping is replicated;
    only the neuron slices are per-device), ``visited_cap`` stays per
    device (hash-owned table shards, as in the dense-row scheme).
    ``backend`` (already resolved + ``lower``-ed into ``comp``) selects
    the per-shard step — jnp sparse math or a fused kernel (DESIGN.md
    §3).  All state is allocated device-side inside one jitted init (no
    host arrays scale with ``S·V``), and the BFS itself is the fused
    while-loop of :func:`_sharded_loop` — the host only syncs at chunk
    boundaries (checkpointing) or at final readout."""
    S, mloc = comp.num_shards, comp.shard_size
    F, V, T = frontier_cap, visited_cap, max_branches
    A = S * V   # global archive rows; each device stores its (A, mloc) slice
    SL = table_slots(V)
    arrs = comp.arrays
    m = comp.num_neurons

    shard = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())
    comp_specs = ShardArrays(
        rule_neuron=P(axis), consume=P(axis), produce=P(axis),
        regex_base=P(axis), regex_period=P(axis), covering=P(axis),
        seg_start=P(axis), seg_count=P(axis), rule_slots=P(),
        in_idx=P(axis), send_idx=P(axis), out_local=P(axis),
        init_loc=P(axis), global_idx=P(axis))

    def put(tree, specs):
        return jax.device_put(
            tree, jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                               is_leaf=lambda x: isinstance(x, P)))

    arrs_dev = put(arrs, comp_specs)

    def _init(init_cols, gidx):
        # column-space init vector + one zobrist over the global position
        # map == the psum of the per-device slice hashes the loop computes
        hi0, lo0 = zobrist_hash(init_cols, positions=gidx)
        hic, loc = _canonical(hi0[None], lo0[None], jnp.ones((1,), bool))
        owner0 = (hic[0] % np.uint32(S)).astype(jnp.int32)
        base0 = _base_slot(hic, loc, SL).astype(jnp.int32)[0]
        init_slices = init_cols.reshape(S, mloc)
        frontier = jnp.zeros((S * F, mloc), jnp.int32).at[
            jnp.arange(S) * F].set(init_slices)
        fvalid = jnp.zeros((F,), bool).at[0].set(True)
        vhi = jnp.full((S * SL,), SENTINEL, jnp.uint32).at[
            owner0 * SL + base0].set(hic[0])
        vlo = jnp.full((S * SL,), SENTINEL, jnp.uint32).at[
            owner0 * SL + base0].set(loc[0])
        vpay = jnp.full((S * SL,), -1, jnp.int32).at[
            owner0 * SL + base0].set(0)
        vcount = jnp.zeros((S,), jnp.int32).at[owner0].set(1)
        archive = jnp.zeros((S * A, mloc), jnp.int32).at[
            jnp.arange(S) * A].set(init_slices)
        return (frontier, fvalid, vhi, vlo, vpay, vcount, archive,
                jnp.asarray(1, jnp.int32), jnp.zeros((S, 3), bool),
                jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))

    state_shardings = (shard, repl, shard, shard, shard, shard, shard,
                       repl, shard, repl, repl)
    gidx = arrs.global_idx.reshape(-1)
    if init is None:
        init_cols = arrs.init_loc.reshape(-1)
    else:
        pad = S * mloc - m
        init_g = jnp.concatenate(
            [jnp.asarray(init, jnp.int32), jnp.zeros((pad,), jnp.int32)])
        init_cols = init_g[gidx]
    state = jax.jit(_init, out_shardings=state_shardings)(init_cols, gidx)

    kw = dict(axis=axis, ndev=S, mloc=mloc, hmax=comp.halo_width,
              max_branches=T, visited_cap=V, backend=backend)
    state_in = (P(axis), P(), P(axis), P(axis), P(axis), P(axis), P(axis),
                P(), P(axis), P(), P())
    state_out = state_in
    # The dense operands are the largest arrays in the scheme — only ship
    # them to devices when the selected backend's step actually consumes
    # them (a pre-lowered comp may carry them for a different backend).
    if comp.dense is not None and isinstance(backend, PallasBackend):
        # Dense kernel operands ride the same device axis as the shard
        # encodings (one slice per device).
        dense_specs = DenseShardArrays(
            M_local=P(axis), onehot=P(axis), hadj=P(axis))
        body = functools.partial(_sharded_loop_dense, **kw)
        in_specs = (comp_specs, dense_specs) + state_in + (P(),)
        lead = (arrs_dev, put(comp.dense, dense_specs))
    else:
        body = functools.partial(_sharded_loop_nodense, **kw)
        in_specs = (comp_specs,) + state_in + (P(),)
        lead = (arrs_dev,)

    loop_fn = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=state_out,
            check_rep=False,
        ))

    state = _run_fused_loop(
        loop_fn, lead, state, max_steps=max_steps,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        fault_injector=fault_injector)

    (_, _, _, _, _, _, archive, archive_n, flags, step,
     total_new) = jax.device_get(state)
    n = int(archive_n)
    if n:
        # columns back to global neuron order via the partition's
        # column→neuron map (identity for contiguous shards)
        cols = np.concatenate(list(archive.reshape(S, A, mloc)),
                              axis=1)[:n]
        configs = np.zeros((n, S * mloc), np.int32)
        configs[:, jax.device_get(gidx)] = cols
        configs = configs[:, :m]
    else:
        configs = np.zeros((0, m), np.int32)
    flags = flags.reshape(S, 3).any(axis=0)
    return ExploreResult(
        configs=configs,
        num_discovered=n,
        steps=int(step),
        exhausted=int(total_new) == 0 and not flags.any(),
        branch_overflow=bool(flags[0]),
        frontier_overflow=bool(flags[1]),
        visited_overflow=bool(flags[2]),
    )


def explore_distributed(
    system: SNPSystem | CompiledAny | ShardedCompiled,
    *,
    mesh: Optional[Mesh] = None,
    max_steps: int = 64,
    frontier_cap: int = 64,       # per device (global under a sharded plan)
    visited_cap: int = 2048,      # per device
    max_branches: int = 32,
    send_cap: Optional[int] = None,   # per (src,dst) pair
    init: Optional[Sequence[int]] = None,
    backend: Optional[BackendLike] = None,
    plan: Optional[SystemPlan] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 32,
    fault_injector=None,
) -> ExploreResult:
    """Hash-partitioned multi-device BFS.  Semantics identical to
    :func:`repro.core.engine.explore`; scaling is linear in devices for
    frontier/visited capacity and expansion FLOPs.

    ``checkpoint_dir``/``checkpoint_every`` snapshot the sharded device
    state between BFS levels (the host-driven per-step loop is the
    natural boundary) and resume from the latest snapshot on re-entry,
    exactly like the single-device :func:`~repro.core.engine.explore`;
    restored arrays are re-``device_put`` with the live mesh's shardings.
    ``fault_injector`` kills scheduled levels deterministically.

    ``backend`` selects the per-shard transition implementation (same
    registry as the single-chip engine — :mod:`repro.core.backend`); each
    device runs ``backend.expand`` on its frontier shard inside the
    shard_map body, so e.g. the fused Pallas kernel or the sparse ELL path
    serves the expansion on every chip with no changes here.

    ``plan`` (:class:`~repro.core.plan.SystemPlan`) selects the storage
    layout.  With ``plan.num_shards == ndev`` the run switches to the
    **neuron-axis-sharded** scheme (module docstring / DESIGN.md §2):
    every frontier/archive row carries only its device's neuron slice and
    the per-step exchange is the static halo of boundary-crossing
    synapses, ``O(touched)`` instead of ``O(m)``.  Any backend whose
    lowering registry declares ``"sharded"`` serves that path — the jnp
    sparse math (``"ref"``/``"sparse"``) or the fused kernels consuming a
    shard's extended-index encoding (``"pallas"``/``"sparse_pallas"``,
    DESIGN.md §3 "Kernel lowering"); ``frontier_cap`` is then the global
    frontier width.

    ``backend=None`` (the default) hands the choice to the query planner
    under the default ``SystemPlan(mode="auto")``, exactly like the
    single-device :func:`~repro.core.engine.explore` — the planner only
    picks sharded-capable backends when ``plan.num_shards > 1``."""
    mesh, axis = _flat_mesh(mesh)
    ndev = mesh.devices.size
    # resolve_entry also folds plan.kernel into the backend instance, and
    # the backend instance is what keys every downstream executable cache
    # (jit static args here, _traces_shard_fn's lru key below) — so two
    # block configurations can never collide into one cached executable.
    be, plan = resolve_entry(system, backend, plan,
                             workload=(frontier_cap, max_branches))
    sharded_plan = plan.num_shards > 1
    if is_sharded(system) or sharded_plan:
        if is_sharded(system):
            comp = system
        else:
            if not isinstance(system, SNPSystem):
                raise ValueError(
                    "neuron-axis sharded exploration needs the SNPSystem "
                    "(or a pre-lowered ShardedCompiled), not a single-"
                    f"device encoding ({type(system).__name__})")
            comp = compile_sharded(system, plan)
        if comp.num_shards != ndev:
            raise ValueError(
                f"plan.num_shards ({comp.num_shards}) must equal the mesh "
                f"device count ({ndev}); build the plan with "
                "sharding.specs.neuron_axis(ndev)")
        if not supports_sharded(be):
            raise ValueError(
                f"backend {be.name!r} does not declare the 'sharded' "
                "encoding in its lowering registry "
                "(StepBackend.supported_encodings), so it cannot step a "
                "neuron shard; every built-in backend supports it")
        comp = lower_with_backend(be, comp, comp.plan)
        return _explore_neuron_sharded(
            comp, mesh, axis, be, max_steps=max_steps,
            frontier_cap=frontier_cap, visited_cap=visited_cap,
            max_branches=max_branches, init=init,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            fault_injector=fault_injector)
    comp = lower_with_backend(be, system, plan) if is_compiled(system) \
        else compile_with_plan(be, system, plan)
    m = comp.num_neurons
    F, V, T = frontier_cap, visited_cap, max_branches
    C = send_cap if send_cap is not None else max(16, (F * T) // max(ndev, 1))

    SL = table_slots(V)
    c0 = comp.init_config if init is None else jnp.asarray(init, jnp.int32)

    # global state, sharded on the leading device axis; everything is
    # allocated (and the init config hashed + table-inserted) inside one
    # jitted init — no host-side O(ndev·V) arrays, no host hashing.
    shard = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())

    def _init(c0):
        hi0, lo0 = config_hash(c0)
        hic, loc = _canonical(hi0[None], lo0[None], jnp.ones((1,), bool))
        owner0 = (hic[0] % np.uint32(ndev)).astype(jnp.int32)
        base0 = _base_slot(hic, loc, SL).astype(jnp.int32)[0]
        frontier = jnp.zeros((ndev * F, m), jnp.int32).at[owner0 * F].set(c0)
        fvalid = jnp.zeros((ndev * F,), bool).at[owner0 * F].set(True)
        vhi = jnp.full((ndev * SL,), SENTINEL, jnp.uint32).at[
            owner0 * SL + base0].set(hic[0])
        vlo = jnp.full((ndev * SL,), SENTINEL, jnp.uint32).at[
            owner0 * SL + base0].set(loc[0])
        vpay = jnp.full((ndev * SL,), -1, jnp.int32).at[
            owner0 * SL + base0].set(0)
        vcount = jnp.zeros((ndev,), jnp.int32).at[owner0].set(1)
        archive = jnp.zeros((ndev * V, m), jnp.int32).at[owner0 * V].set(c0)
        arch_n = jnp.zeros((ndev,), jnp.int32).at[owner0].set(1)
        return (frontier, fvalid, vhi, vlo, vpay, vcount, archive, arch_n,
                jnp.zeros((ndev, 3), bool), jnp.asarray(0, jnp.int32),
                jnp.asarray(1, jnp.int32))

    state_shardings = (shard,) * 9 + (repl, repl)
    state = jax.jit(_init, out_shardings=state_shardings)(c0)

    state_in = (P(axis),) * 9 + (P(), P())
    loop_fn = jax.jit(
        shard_map(
            functools.partial(_dense_loop, axis=axis, ndev=ndev,
                              max_branches=T, send_cap=C, visited_cap=V,
                              backend=be),
            mesh=mesh,
            in_specs=(P(),) + state_in + (P(),),
            out_specs=state_in,
            # pallas_call has no replication rule; every output spec is
            # explicit anyway, so the check adds nothing here.
            check_rep=False,
        ))

    state = _run_fused_loop(
        loop_fn, (comp,), state, max_steps=max_steps,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        fault_injector=fault_injector)

    (_, _, _, _, _, _, archive, arch_n, flags, step,
     total_new) = jax.device_get(state)
    configs = np.concatenate([
        archive[d * V: d * V + int(arch_n[d])] for d in range(ndev)
    ]) if arch_n.sum() else np.zeros((0, m), np.int32)
    flags = flags.reshape(ndev, 3).any(axis=0)
    return ExploreResult(
        configs=configs,
        num_discovered=int(arch_n.sum()),
        steps=int(step),
        exhausted=int(total_new) == 0 and not flags.any(),
        branch_overflow=bool(flags[0]),
        frontier_overflow=bool(flags[1]),
        visited_overflow=bool(flags[2]),
    )


# ---------------------------------------------------------------------------
# Distributed trace serving: data-parallel run_traces over the mesh
# ---------------------------------------------------------------------------


def run_traces_distributed(
    system: SNPSystem | CompiledAny, *, steps: int,
    seeds: Sequence[int] | np.ndarray | jnp.ndarray,
    policy: str = "first", max_branches: int = 64,
    backend: Optional[BackendLike] = None,
    mesh: Optional[Mesh] = None,
    plan: Optional[SystemPlan] = None,
):
    """Mesh-sharded :func:`repro.core.engine.run_traces` (DESIGN.md §4).

    Trajectories are independent, so serving a batch over ``ndev`` devices
    is pure data parallelism: the batch axis is sharded over the (flattened)
    mesh, each device runs the same per-shard ``lax.scan``, and no
    collectives are needed.  Per-trace PRNG keys mean trace ``b`` depends
    only on ``seeds[b]``, so the result is **bit-identical** to the
    single-device :func:`~repro.core.engine.run_traces` — padding the batch
    up to a mesh multiple (with seed-0 dummies, sliced off on return) is
    therefore free.

    Returns a :class:`~repro.core.engine.TraceOut` of ``(configs
    (B, steps, m), emissions (B, steps), alive (B, steps),
    branch_overflow (B, steps))`` with ``B = len(seeds)``, exactly like
    the single-device path.
    """
    if policy not in ("first", "random"):
        raise ValueError(f"unknown policy {policy!r}")
    if plan is not None and plan.num_shards > 1:
        raise ValueError("trace serving shards the batch axis, not the "
                         "neuron axis; plan.num_shards > 1 is only "
                         "consumed by explore_distributed")
    seeds = np.asarray(seeds, np.uint32)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be 1-D, got shape {seeds.shape}")
    # The planner decides when backend=None (default SystemPlan mode
    # "auto"); _traces_shard_fn's lru cache keys on the resolved backend
    # *instance*, so a plan kernel's block shape is part of the key.
    be, plan, planned = resolve_entry_info(
        system, backend, plan, workload=(int(seeds.shape[0]), max_branches))
    mesh, axis = _flat_mesh(mesh)
    ndev = mesh.devices.size

    B = seeds.shape[0]
    Bp = ((max(B, 1) + ndev - 1) // ndev) * ndev
    padded = np.zeros((Bp,), np.uint32)
    padded[:B] = seeds
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(padded))     # (Bp, 2)

    def attempt(be, plan):
        comp = lower_with_backend(be, system, plan) if is_compiled(system) \
            else compile_with_plan(be, system, plan)
        c0s = jnp.broadcast_to(comp.init_config,
                               (Bp,) + comp.init_config.shape)   # (Bp, m)
        fn = _traces_shard_fn(mesh, axis, steps, max_branches, policy, be)
        out = fn(comp, c0s, keys)
        jax.block_until_ready(out.configs)
        return out

    out = run_with_failover(attempt, be, plan, degradable=planned)
    return TraceOut(*(x[:B] for x in out))


@functools.lru_cache(maxsize=128)
def _traces_shard_fn(mesh, axis, steps, max_branches, policy, backend):
    """One jitted shard_map per (mesh, statics): meshes compare by value,
    so a service calling with an equal mesh every flush reuses the
    executable instead of re-tracing per call."""
    return jax.jit(
        shard_map(
            functools.partial(_traces_scan, steps=steps,
                              max_branches=max_branches, policy=policy,
                              backend=backend),
            mesh=mesh,
            in_specs=(P(), P(axis), P(axis)),
            # one spec broadcast over every TraceOut leaf (batch-sharded)
            out_specs=P(axis),
            # same reasoning as explore_distributed: pallas_call has no
            # replication rule, and every output spec is explicit anyway
            check_rep=False,
        ))
