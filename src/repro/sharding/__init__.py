"""Logical-axis sharding plans (FSDP + TP + EP + SP) for the production
mesh."""

from .specs import ShardingPlan, make_plan, neuron_axis

__all__ = ["ShardingPlan", "make_plan", "neuron_axis"]
