"""Logical-axis sharding plans (FSDP + TP + EP + SP) for the production
mesh."""

from .specs import ShardingPlan, make_plan

__all__ = ["ShardingPlan", "make_plan"]
