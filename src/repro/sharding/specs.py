"""Sharding plan: parameter / batch / cache PartitionSpecs + activation
constraints for FSDP + TP (+ EP when expert count divides an axis, + SP
options).

Axes convention (launch/mesh.py):
* single pod:  ``(data, model)`` = (16, 16)
* multi pod:   ``(pod, data, model)`` = (2, 16, 16) — ``pod`` joins the FSDP
  /batch axes (hierarchical DP); the same plan code covers both.

Parameters are sharded 2-D (FSDP over ``data``(+``pod``) on the reduction
dim, TP over ``model`` on heads/ff/experts) so 314B-398B models fit 256
chips including optimizer state.  Stack params carry a leading
``num_periods`` axis (scan over periods) that is never sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import SystemPlan

__all__ = ["ShardingPlan", "make_plan", "neuron_axis"]


def neuron_axis(num_shards: int, *, encoding: str = "ell",
                hub_threshold: Optional[int] = None,
                partition: str = "contiguous") -> SystemPlan:
    """A :class:`~repro.core.plan.SystemPlan` that partitions the SNP
    neuron axis over ``num_shards`` devices — the plan
    ``explore_distributed`` consumes for its neuron-axis-sharded frontier
    (one shard per device of the flattened 1-D mesh; DESIGN.md §2).
    Build it from a live mesh via :meth:`ShardingPlan.neuron_axis` or
    directly from ``len(jax.devices())``.  Any backend whose lowering
    registry declares ``"sharded"`` steps the shards — including the
    fused kernels (DESIGN.md §3 "Kernel lowering").  ``encoding="hybrid"``
    combined with ``num_shards > 1`` is refused at compile time (the
    per-shard encodings are ELL; hub tails inflate the halo instead).
    ``partition="degree"`` spreads hub neurons across shards by greedy
    degree-weighted bin-packing instead of contiguous slices
    (:func:`repro.core.plan.partition_neurons`)."""
    return SystemPlan(encoding=encoding, hub_threshold=hub_threshold,
                      num_shards=num_shards, partition=partition)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    fsdp: Tuple[str, ...]         # ('data',) or ('pod', 'data')
    tp: str                       # 'model'
    # options (hillclimb knobs)
    seq_shard_activations: bool = False   # SP: shard S of the residual stream
    shard_kv_seq: bool = True             # serving: KV cache S over tp

    # ---- divisibility fitting --------------------------------------------
    def _axes_size(self, axes) -> int:
        out = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            out *= self.mesh.shape[a]
        return out

    def fit(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Drop mesh axes from dims they don't divide (e.g. 5 KV heads on a
        16-way model axis fall back to replication; batch 1 on a 32-way DP
        axis keeps only the divisible sub-axes).  Tuples shed their
        outermost axis first ('pod' before 'data')."""
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, entry in zip(shape, entries):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            while axes and dim % self._axes_size(axes) != 0:
                axes = axes[1:]
            out.append(axes if len(axes) > 1 else
                       (axes[0] if axes else None))
        return P(*out)

    def _fit_tree(self, spec_tree, leaf_tree):
        return jax.tree.map(
            lambda s, l: self.fit(s, tuple(l.shape)), spec_tree, leaf_tree,
            is_leaf=lambda x: isinstance(x, P))

    # ---- sizes -----------------------------------------------------------
    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.fsdp

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.fsdp:
            out *= self.mesh.shape[a]
        return out

    # ---- parameter specs ---------------------------------------------------
    def param_specs(self, cfg: ArchConfig, params_tree) -> Any:
        f, t = self.fsdp, self.tp
        ep_ok = cfg.num_experts and cfg.num_experts % self.tp_size == 0

        def rule(path: str, ndim: int) -> P:
            def pad(spec: P) -> P:
                # stack params carry the leading periods axis
                if "stack/" in path and len(spec) < ndim:
                    return P(*((None,) + tuple(spec)))
                return spec

            name = path.rsplit("/", 1)[-1]
            # --- embeddings / head
            if name == "embed":
                return P(t, f) if ndim == 2 else P(None, t, f)
            if name == "head":
                return P(f, t) if ndim == 2 else P(None, f, t)
            # --- 1-d (norm scales, biases on vectors)
            base_ndim = ndim - (1 if "stack/" in path else 0)
            if base_ndim <= 1:
                return pad(P(None))
            # --- attention
            if name in ("wq", "wk", "wv"):
                return pad(P(f, t, None))
            if name == "wo" and "attn" in path:
                return pad(P(t, None, f))
            if name in ("bq", "bk", "bv"):
                return pad(P(t, None))
            if name in ("wdq", "wdkv"):
                return pad(P(f, None))
            if name in ("wuq", "wuk", "wuv"):
                return pad(P(None, t, None))
            # --- moe
            if name == "router":
                return pad(P(f, None))
            if "moe" in path and name in ("wg", "wu"):
                return pad(P(t, f, None) if ep_ok else P(None, f, t))
            if "moe" in path and name == "wd":
                return pad(P(t, None, f) if ep_ok else P(None, t, f))
            # --- dense mlp
            if name in ("wg", "wu"):
                return pad(P(f, t))
            if name == "wd":
                return pad(P(t, f))
            # --- mamba
            if name == "in_proj":
                return pad(P(f, t))
            if name == "conv_w":
                return pad(P(None, t))
            if name == "x_proj":
                return pad(P(t, None))
            if name == "dt_proj_w":
                return pad(P(None, t))
            if name == "a_log":
                return pad(P(t, None))
            if name == "out_proj":
                return pad(P(t, f))
            # --- rwkv
            if name in ("wr", "wk", "wv", "wg", "cm_wk", "cm_wr"):
                return pad(P(f, t))
            if name in ("wo", "cm_wv"):
                return pad(P(t, f))
            if name == "maa_w1":
                return pad(P(f, None))
            if name == "maa_w2":
                return pad(P(None, None, f))
            if name == "decay_w1":
                return pad(P(f, None))
            if name == "decay_w2":
                return pad(P(None, f))
            if name == "bonus":
                return pad(P(t, None))
            if name == "maa_rkvwg":
                return pad(P(None, None))
            # fallback: replicate
            return pad(P(*([None] * ndim)))

        def walk(path, leaf):
            keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return self.fit(rule(keys, leaf.ndim), tuple(leaf.shape))

        return jax.tree_util.tree_map_with_path(walk, params_tree)

    # ---- batch specs -------------------------------------------------------
    def batch_specs(self, cfg: ArchConfig, batch_tree) -> Any:
        f = self.fsdp

        def spec(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            if name in ("tokens", "labels"):
                s = P(f, None, None) if leaf.ndim == 3 else P(f, None)
            elif name == "positions":
                s = P(None, f, None) if leaf.ndim == 3 else P(f, None)
            elif name == "frontend_embeds":
                s = P(f, None, None)
            elif name == "embed_mask":
                s = P(f, None)
            else:
                s = P(*([None] * leaf.ndim))
            return self.fit(s, tuple(leaf.shape))

        return jax.tree_util.tree_map_with_path(spec, batch_tree)

    # ---- cache specs -------------------------------------------------------
    def cache_specs(self, cfg: ArchConfig, cache_tree) -> Any:
        f, t = self.fsdp, self.tp
        seq = t if self.shard_kv_seq else None

        def spec(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            nd = leaf.ndim   # all carry leading periods axis
            if name in ("k", "v"):           # (P,B,S,Hk,hd)
                s = P(None, f, seq, None, None)
            elif name == "ckv":              # (P,B,S,rank)
                s = P(None, f, seq, None)
            elif name == "k_rope":           # (P,B,S,1,dr)
                s = P(None, f, seq, None, None)
            elif name == "len":
                s = P(None, f)
            elif name == "conv":             # (P,B,dconv-1,din)
                s = P(None, f, None, t)
            elif name == "ssm":              # (P,B,din,n)
                s = P(None, f, t, None)
            elif name == "state":            # (P,B,H,hs,hs)
                s = P(None, f, t, None, None)
            elif name in ("tm_shift", "cm_shift"):   # (P,B,D)
                s = P(None, f, None)
            else:
                s = P(*([None] * nd))
            return self.fit(s, tuple(leaf.shape))

        return jax.tree_util.tree_map_with_path(spec, cache_tree)

    # ---- activation constraints ---------------------------------------------
    def constrain(self, x: jnp.ndarray, kind: str) -> jnp.ndarray:
        f, t = self.fsdp, self.tp
        seq = t if self.seq_shard_activations else None
        table = {
            "hidden": P(f, seq, None),
            "heads": P(f, None, t, None),
            "heads_v": P(f, None, t, None),
            "logits": P(f, None, t),
            # expert activations: E over model when divisible; D over the
            # FSDP axes so expert-weight contractions reduce activations
            # (psum of (E,C,·)) instead of all-gathering the weights
            "expert_in": P(t, None, f) if self._ep_ok_cached(x) else
                         P(None, None, t),
            "mamba_inner": P(f, None, t),
            "moe_chunks": P(None, f, None),   # (n_chunks, Tc, D)
            "moe_tokens": P(f, None),         # (T, D)
            # decode (single-token) residual stream: shard D over the FSDP
            # axes so weight contractions reduce tiny activations instead
            # of all-gathering weight shards every step
            "hidden_decode": P(None, None, f),
        }
        spec = table.get(kind)
        if spec is None or len(spec) != x.ndim:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.fit(spec, tuple(x.shape))))

    def _ep_ok_cached(self, x) -> bool:
        return x.shape[0] % self.tp_size == 0

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ---- SNP partition planning ---------------------------------------------
    def neuron_axis(self, *, encoding: str = "ell",
                    hub_threshold: Optional[int] = None,
                    partition: str = "contiguous") -> SystemPlan:
        """Neuron-axis :class:`~repro.core.plan.SystemPlan` sized to this
        plan's mesh: all devices (model/TP axes included — SNP exploration
        is pure data parallelism) contribute one neuron shard each.  Pair
        it with :meth:`trace_mesh`'s flattening convention and pass to
        ``explore_distributed(plan=...)``."""
        return neuron_axis(int(self.mesh.devices.size), encoding=encoding,
                           hub_threshold=hub_threshold, partition=partition)

    # ---- SNP trace serving --------------------------------------------------
    def trace_mesh(self) -> Mesh:
        """The 1-D serving mesh for
        :func:`repro.core.distributed.run_traces_distributed`: all devices
        of the plan's mesh flattened onto one ``traces`` axis — trace
        serving is pure data parallelism (DESIGN.md §4), so the model/TP
        axes contribute their devices to the batch partition instead of
        idling.  Requires a concrete mesh (AbstractMesh has no devices)."""
        return Mesh(self.mesh.devices.reshape(-1), ("traces",))


def make_plan(mesh: Mesh, **opts) -> ShardingPlan:
    names = mesh.axis_names
    if "pod" in names:
        fsdp: Tuple[str, ...] = ("pod", "data")
    else:
        fsdp = ("data",)
    return ShardingPlan(mesh=mesh, fsdp=fsdp, tp="model", **opts)
