"""Elastic re-meshing: resume the same global state on a different device
count.

Because (a) checkpoints are topology-independent (host numpy + manifest)
and (b) every sharding is derived from the mesh by ``make_plan``, scaling
down (node loss) or up (capacity arrives) is: build new mesh -> rebuild
plan/specs -> ``restore_checkpoint`` with the new NamedShardings -> rebuild
the jitted step.  Nothing about the model or optimizer state changes; only
the ``data`` axis extent (and therefore per-device batch) moves.

``choose_mesh_shape`` picks the largest usable (data, model) grid for a
surviving device count, keeping the model axis intact first (TP size is a
property of the model's memory footprint, DP is the elastic axis).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["choose_mesh_shape", "build_mesh"]


def choose_mesh_shape(num_devices: int, model_axis: int,
                      pod_axis: Optional[int] = None) -> Tuple[int, ...]:
    """Largest (pod?, data, model) grid with <= num_devices devices.

    Keeps ``model_axis`` fixed (shrinking TP changes per-device memory);
    drops to the largest data extent that fits, then the pod axis.
    """
    if model_axis > num_devices:
        raise ValueError(
            f"cannot keep model axis {model_axis} with only "
            f"{num_devices} devices")
    if pod_axis:
        for pods in range(pod_axis, 0, -1):
            data = num_devices // (pods * model_axis)
            if data >= 1:
                return (pods, data, model_axis)
    data = num_devices // model_axis
    return (data, model_axis)


def build_mesh(shape: Sequence[int],
               devices: Optional[Sequence] = None) -> Mesh:
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    devs = np.array(devices if devices is not None else jax.devices())
    need = int(np.prod(shape))
    if devs.size < need:
        raise ValueError(f"need {need} devices, have {devs.size}")
    return Mesh(devs[:need].reshape(shape), names)
