"""Fault-tolerant training supervisor: checkpoint/restart, failure
injection, straggler detection.

At fleet scale the dominant failure mode is a node dropping mid-step; the
recovery contract here is the standard one (MaxText/Pathways posture):

1. train loop runs under a supervisor that snapshots state every
   ``ckpt_every`` steps (async — the loop never blocks on I/O),
2. on failure (real exception, or injected by tests via ``FailureInjector``)
   the supervisor restores the latest complete checkpoint — atomic rename
   guarantees completeness — rebuilds the step function (possibly on a new
   mesh: :mod:`repro.runtime.elastic`), and replays the data stream from the
   checkpointed step (the pipeline is a pure function of step — no data
   loss, no double-consumption),
3. per-step wall-times feed a straggler detector
   (:mod:`repro.runtime.straggler`) whose mitigation decision is exercised
   in tests with synthetic timings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore_checkpoint)

__all__ = ["FailureInjector", "Supervisor", "SupervisorConfig"]


class FailureInjector:
    """Deterministic failure schedule for tests: raises ``RuntimeError`` the
    first time each listed step is reached."""

    def __init__(self, fail_at_steps=()):
        self.remaining = set(fail_at_steps)

    def check(self, step: int):
        if step in self.remaining:
            self.remaining.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 10
    max_restarts: int = 3
    keep: int = 3


class Supervisor:
    """Runs ``num_steps`` of training with checkpoint/restart semantics.

    ``make_step``: () -> (state, step_fn, start_step) — called at start and
    after every failure, so a re-mesh/elastic rebuild can happen inside.
    ``data_for``: step -> batch (pure).
    """

    def __init__(self, cfg: SupervisorConfig,
                 make_step: Callable[[Optional[int]], Tuple[Any, Callable]],
                 data_for: Callable[[int], Any],
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.make_step = make_step
        self.data_for = data_for
        self.injector = injector
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.restarts = 0
        self.step_times: list[float] = []

    def run(self, num_steps: int) -> Tuple[Any, Dict]:
        state, step_fn, start = self.make_step(None)
        step = start
        metrics: Dict = {}
        while step < num_steps:
            try:
                while step < num_steps:
                    if self.injector is not None:
                        self.injector.check(step)
                    t0 = time.monotonic()
                    batch = self.data_for(step)
                    state, metrics = step_fn(state, batch)
                    self.step_times.append(time.monotonic() - t0)
                    step += 1
                    if step % self.cfg.ckpt_every == 0:
                        self.ckpt.save(step, state)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from e
                self.ckpt.wait()
                restored = latest_step(self.cfg.ckpt_dir)
                state, step_fn, _ = self.make_step(restored)
                step = restored if restored is not None else start
        self.ckpt.wait()
        return state, {"final_step": step, "restarts": self.restarts,
                       **{k: float(np.asarray(v)) for k, v in metrics.items()}}
