"""Failure-domain primitives for the SNP serving and exploration paths.

The paper's matrix semantics make every transition a deterministic
function of the configuration and (for traces) the per-request PRNG seed,
which is exactly the property that makes aggressive recovery-by-
re-execution safe: re-running an already-good trace is free of harm, and
a BFS resumed from a snapshot of its device state is bit-identical to an
uninterrupted run.  This module holds the policy/injection vocabulary the
recovery machinery shares (DESIGN.md §4.4 "Failure domains"):

* :class:`FaultPolicy` — how a service reacts to failures: bounded
  retries with exponential backoff + *deterministic* jitter, per-request
  deadlines, admission control, and whether to bisect failing chunks /
  degrade backends.  Carried by
  :class:`~repro.serve.snp_service.SNPTraceService` and
  ``launch/serve.py --snp``.
* :class:`FaultInjector` — a deterministic fault schedule for tests and
  the ``serve_fault`` bench tier: "fail the Nth device call" (transient —
  fires once), "stall call K" (deadline pressure), "poison seed X"
  (persistent — every call whose batch contains that seed fails), and
  "fail the Nth compile".  One shared thread-safe call counter threads
  through the service runner, the engine's chunked explore loop, and the
  distributed per-step loops, so a single schedule exercises every
  recovery path.
* :func:`run_supervised` — the SNP-side analogue of
  :class:`repro.runtime.fault_tolerance.Supervisor`: re-invoke a
  checkpoint-resuming callable (e.g. :func:`repro.core.engine.explore`
  with ``checkpoint_dir=``) until it completes, bounding restarts.

The exception taxonomy is part of the recovery contract:
:class:`DeadlineExceeded` and :class:`AdmissionRejected` are *caller*
outcomes (the request never consumed device time);
:class:`InjectedFault` is transient (a retry may clear it);
:class:`PoisonError` is persistent (retries never clear it — only
bisection isolates the culprit).
"""

from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import numpy as np

__all__ = ["FaultPolicy", "FaultInjector", "InjectedFault", "PoisonError",
           "DeadlineExceeded", "AdmissionRejected", "run_supervised"]


class InjectedFault(RuntimeError):
    """A scheduled transient failure: the injector raises it once per
    scheduled call ordinal, so a retry of the same work succeeds."""


class PoisonError(InjectedFault):
    """A scheduled *persistent* failure: raised on every device call whose
    batch contains a poisoned seed.  Retries can never clear it; only
    bisecting the chunk isolates the culprit request."""


class DeadlineExceeded(TimeoutError):
    """The request's ``deadline_ms`` elapsed before it reached the device;
    it fails fast without consuming device time."""


class AdmissionRejected(RuntimeError):
    """``FaultPolicy.max_pending`` admission control rejected the request
    at submit time instead of growing the queue without bound."""


@dataclass(frozen=True)
class FaultPolicy:
    """How a serving/exploration path reacts to failures.

    * ``max_retries``    — whole-chunk re-runs after the first failure
      (exponential backoff between attempts).
    * ``backoff_ms`` / ``backoff_factor`` / ``jitter`` — attempt ``k``
      sleeps ``backoff_ms * backoff_factor**k`` scaled by up to
      ``+jitter`` *deterministic* jitter (a CRC of the attempt and chunk
      identity — reproducible schedules, no thundering herd).
    * ``deadline_ms``    — default per-request deadline; a request older
      than this fails fast with :class:`DeadlineExceeded` before the
      device call.  ``TraceRequest.deadline_ms`` overrides per request.
    * ``max_pending``    — admission control: ``submit`` raises
      :class:`AdmissionRejected` once this many requests are queued.
    * ``bisect``         — after retries are exhausted, split the chunk in
      half and recurse, isolating poison requests so only the culprit's
      future carries the exception (re-running good traces is free by
      seed-determinism).
    * ``degrade``        — after retries are exhausted, walk the
      encoding-compatible backend degrade chain
      (:mod:`repro.core.failover`) before bisecting.
    """

    max_retries: int = 2
    backoff_ms: float = 10.0
    backoff_factor: float = 2.0
    jitter: float = 0.1
    deadline_ms: Optional[float] = None
    max_pending: Optional[int] = None
    bisect: bool = True
    degrade: bool = True

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_ms < 0 or self.backoff_factor < 1 or self.jitter < 0:
            raise ValueError("backoff_ms >= 0, backoff_factor >= 1 and "
                             "jitter >= 0 required")

    def backoff_s(self, attempt: int, token: Any = 0) -> float:
        """Seconds to sleep before retry ``attempt`` (0-based).  Jitter is
        a pure function of (attempt, token) — deterministic and
        schedule-reproducible, but decorrelated across chunks."""
        base = self.backoff_ms * (self.backoff_factor ** attempt) / 1e3
        frac = (zlib.crc32(f"{attempt}:{token}".encode()) % 1024) / 1023.0
        return base * (1.0 + self.jitter * frac)


class FaultInjector:
    """Deterministic fault schedule shared by every SNP recovery path.

    * ``fail_calls``  — 1-based device-call ordinals that raise
      :class:`InjectedFault` **once** each (transient).
    * ``slow_calls``  — ``{ordinal: seconds}`` stalls injected before the
      call runs (deadline pressure: "timeout flush K").
    * ``poison_seeds`` — any device call whose seed batch contains one of
      these raises :class:`PoisonError` **every time** (persistent;
      poisoned seeds must be nonzero — batch padding uses seed 0).
    * ``fail_compiles`` — 1-based compile ordinals that raise once each.

    One thread-safe counter is shared between the wrapped service runner
    (:meth:`runner`), the engine's chunked explore loop and the
    distributed per-step loops (:meth:`on_device_call`), so a single
    schedule is meaningful across all three.
    """

    def __init__(self, *, fail_calls: Iterable[int] = (),
                 slow_calls: Optional[Dict[int, float]] = None,
                 poison_seeds: Iterable[int] = (),
                 fail_compiles: Iterable[int] = (),
                 error_factory: Optional[Callable[[int], Exception]] = None,
                 ) -> None:
        self.fail_calls = set(int(n) for n in fail_calls)
        self.slow_calls = dict(slow_calls or {})
        self.poison_seeds = frozenset(int(s) for s in poison_seeds)
        if 0 in self.poison_seeds:
            raise ValueError("poison seed 0 would also match batch padding")
        self.fail_compiles = set(int(n) for n in fail_compiles)
        self.error_factory = error_factory
        self.calls = 0
        self.compiles = 0
        self.injected = 0
        self._lock = threading.Lock()

    def on_device_call(self, seeds=None) -> int:
        """Advance the call counter; raise if this ordinal (or a poisoned
        seed in ``seeds``) is scheduled.  Returns the ordinal."""
        with self._lock:
            self.calls += 1
            n = self.calls
            fire = n in self.fail_calls
            if fire:
                self.fail_calls.discard(n)   # transient: fires once
        if n in self.slow_calls:
            time.sleep(self.slow_calls[n])
        # transient infrastructure faults fire regardless of payload, so a
        # scheduled ordinal is never masked by a poison request riding in
        # the same batch (the poison fires on the retry instead)
        if fire:
            with self._lock:
                self.injected += 1
            if self.error_factory is not None:
                raise self.error_factory(n)
            raise InjectedFault(f"injected failure at device call {n}")
        if seeds is not None and self.poison_seeds:
            present = self.poison_seeds.intersection(
                int(s) for s in np.asarray(seeds).reshape(-1).tolist())
            if present:
                with self._lock:
                    self.injected += 1
                raise PoisonError(
                    f"injected poison request (seed {sorted(present)}) "
                    f"at device call {n}")
        return n

    def on_compile(self, system=None) -> int:
        with self._lock:
            self.compiles += 1
            n = self.compiles
            fire = n in self.fail_compiles
            if fire:
                self.fail_compiles.discard(n)
        if fire:
            with self._lock:
                self.injected += 1
            raise InjectedFault(f"injected failure at compile {n}")
        return n

    def runner(self, inner: Callable) -> Callable:
        """Wrap a :func:`~repro.core.engine.run_traces`-compatible runner
        so every device call passes through the schedule first."""
        def wrapped(comp, *, seeds, **kw):
            self.on_device_call(seeds=seeds)
            return inner(comp, seeds=seeds, **kw)
        return wrapped


def run_supervised(fn: Callable[[], Any], *, max_restarts: int = 3,
                   restartable: Tuple[type, ...] = (Exception,),
                   ) -> Tuple[Any, int]:
    """Re-invoke ``fn`` until it completes; returns ``(result, restarts)``.

    The SNP-side supervisor: ``fn`` must be resumable from its own durable
    state — e.g. a closure over :func:`repro.core.engine.explore` with
    ``checkpoint_dir=`` set, which restores the latest complete snapshot
    on entry — so each restart continues instead of starting over.
    Raises ``RuntimeError`` (chaining the last failure) once
    ``max_restarts`` is exceeded; never swallows ``KeyboardInterrupt``.
    """
    restarts = 0
    while True:
        try:
            return fn(), restarts
        except restartable as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={max_restarts}") from e
