"""Straggler detection + mitigation policy.

On a synchronous mesh a straggling host delays every collective; the
mitigation ladder implemented here (decision logic is unit-tested; the
actuation hooks are wired in the Supervisor):

1. detect: per-step durations beyond ``threshold`` x rolling median for
   ``patience`` consecutive steps,
2. mitigate-soft: shrink the straggler's microbatch share (bounded-staleness
   gradient accumulation — returns a rebalanced share map),
3. mitigate-hard: recommend eviction -> elastic re-mesh
   (:mod:`repro.runtime.elastic`) + restore.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

__all__ = ["StragglerConfig", "StragglerDetector", "rebalance_shares"]


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    window: int = 32          # rolling-median window
    threshold: float = 1.5    # x median counts as straggling
    patience: int = 3         # consecutive slow steps before flagging
    evict_after: int = 10     # flagged steps before recommending eviction


class StragglerDetector:
    def __init__(self, cfg: StragglerConfig, num_hosts: int):
        self.cfg = cfg
        self.history = [deque(maxlen=cfg.window) for _ in range(num_hosts)]
        self.slow_streak = np.zeros(num_hosts, int)
        self.flagged_steps = np.zeros(num_hosts, int)

    def observe(self, host_times: List[float]) -> Dict[str, object]:
        """Feed one step's per-host durations; returns the decision."""
        for h, t in enumerate(host_times):
            self.history[h].append(t)
        med = np.median([t for dq in self.history for t in dq])
        slow = np.array([t > self.cfg.threshold * med for t in host_times])
        self.slow_streak = np.where(slow, self.slow_streak + 1, 0)
        flagged = self.slow_streak >= self.cfg.patience
        self.flagged_steps += flagged.astype(int)
        evict = np.nonzero(self.flagged_steps >= self.cfg.evict_after)[0]
        return {
            "median": float(med),
            "stragglers": np.nonzero(flagged)[0].tolist(),
            "evict": evict.tolist(),
        }


def rebalance_shares(base_microbatches: int, num_hosts: int,
                     stragglers: List[int],
                     slowdown: float = 2.0) -> List[int]:
    """Bounded-staleness share rebalance: stragglers get fewer microbatches,
    fast hosts absorb them; total preserved (gradient stays unbiased under
    re-weighting by actual share)."""
    shares = [base_microbatches] * num_hosts
    if not stragglers or len(stragglers) >= num_hosts:
        return shares
    give = 0
    for h in stragglers:
        reduced = max(1, int(base_microbatches / slowdown))
        give += shares[h] - reduced
        shares[h] = reduced
    fast = [h for h in range(num_hosts) if h not in stragglers]
    for i in range(give):
        shares[fast[i % len(fast)]] += 1
    assert sum(shares) == base_microbatches * num_hosts
    return shares
