"""Fleet runtime: fault tolerance, straggler mitigation, elastic scaling."""

from .elastic import build_mesh, choose_mesh_shape
from .fault_tolerance import FailureInjector, Supervisor, SupervisorConfig
from .faults import (AdmissionRejected, DeadlineExceeded, FaultInjector,
                     FaultPolicy, InjectedFault, PoisonError, run_supervised)
from .straggler import StragglerConfig, StragglerDetector, rebalance_shares

__all__ = ["FailureInjector", "Supervisor", "SupervisorConfig",
           "FaultPolicy", "FaultInjector", "InjectedFault", "PoisonError",
           "DeadlineExceeded", "AdmissionRejected", "run_supervised",
           "StragglerConfig", "StragglerDetector", "rebalance_shares",
           "build_mesh", "choose_mesh_shape"]
