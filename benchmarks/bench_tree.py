"""Benchmark: full computation-tree exploration (paper §5 run + Fig. 4).

Measures end-to-end BFS throughput (configurations discovered per second)
on the paper's Π, scaled copies of it, and random systems — the direct
analog of the paper's simulation runs, where the entire loop is the
measured quantity.  The loop itself is the engine's on-device
``lax.while_loop``; the transition comes from the step-backend registry,
so ``ref`` and ``pallas`` exercise the identical BFS machinery.
"""

import time

from repro.core import compile_system, explore, paper_pi
from repro.core.generators import nd_chain, random_system, scaled_pi

# (name, system, explore kwargs, backends to sweep).  Pallas interpret mode
# is swept only on the paper's own Π to keep CPU bench runs short.
CASES = [
    ("pi", lambda: compile_system(paper_pi(True)),
     dict(max_steps=16, frontier_cap=128, visited_cap=2048,
          max_branches=16), ("ref", "pallas")),
    ("pi_x4", lambda: compile_system(scaled_pi(4)),
     dict(max_steps=6, frontier_cap=512, visited_cap=16384,
          max_branches=64), ("ref",)),
    ("random_64n", lambda: compile_system(random_system(64, 2, 0.08, seed=5)),
     dict(max_steps=8, frontier_cap=512, visited_cap=16384,
          max_branches=64), ("ref",)),
    ("nd_chain_6", lambda: compile_system(nd_chain(6)),
     dict(max_steps=8, frontier_cap=512, visited_cap=8192,
          max_branches=64), ("ref",)),
]


def rows():
    out = []
    for name, make, kw, backends in CASES:
        comp = make()
        for backend in backends:
            explore(comp, backend=backend, **kw)  # warm compile
            t0 = time.perf_counter()
            res = explore(comp, backend=backend, **kw)
            dt = time.perf_counter() - t0
            us = dt * 1e6
            out.append((f"explore/{backend}/{name}", us / max(res.steps, 1),
                        f"{res.num_discovered}cfg@{res.steps}lvl,"
                        f"{res.num_discovered / dt:.0f}cfg/s"))
    return out
