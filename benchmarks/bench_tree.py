"""Benchmark: full computation-tree exploration (paper §5 run + Fig. 4).

Measures end-to-end BFS throughput (configurations discovered per second)
on the paper's Π, scaled copies of it, random systems, and bounded-degree
sparse topologies — the direct analog of the paper's simulation runs,
where the entire loop is the measured quantity.  The loop itself is the
engine's on-device ``lax.while_loop``; the transition comes from the
step-backend registry, so every backend exercises the identical BFS
machinery.  Each backend explores its own lowering (``backend.compile``),
so e.g. the sparse rows never touch a dense ``M_Π``.
"""

import time

from repro.core import explore, get_backend, paper_pi
from repro.core.generators import (nd_chain, random_system, ring_lattice,
                                   scaled_pi, torus)

# (name, system, explore kwargs, backends to sweep).  Interpret-mode kernel
# backends are swept only on the paper's own Π to keep CPU bench runs short.
CASES = [
    ("pi", paper_pi(True),
     dict(max_steps=16, frontier_cap=128, visited_cap=2048,
          max_branches=16), ("ref", "pallas", "sparse", "sparse_pallas")),
    ("pi_x4", scaled_pi(4),
     dict(max_steps=6, frontier_cap=512, visited_cap=16384,
          max_branches=64), ("ref", "sparse")),
    ("random_64n", random_system(64, 2, 0.08, seed=5),
     dict(max_steps=8, frontier_cap=512, visited_cap=16384,
          max_branches=64), ("ref", "sparse")),
    ("nd_chain_6", nd_chain(6),
     dict(max_steps=8, frontier_cap=512, visited_cap=8192,
          max_branches=64), ("ref", "sparse")),
    # bounded-degree sparse tier: dense BFS at this size means a dense
    # M_Π per expansion; sparse-only past the torus cross-over point.
    ("torus_16x16", torus(16, 16, seed=3),
     dict(max_steps=4, frontier_cap=256, visited_cap=4096,
          max_branches=32), ("ref", "sparse")),
    ("ring_lattice_1024d4", ring_lattice(1024, 4, seed=3),
     dict(max_steps=3, frontier_cap=128, visited_cap=2048,
          max_branches=16), ("sparse",)),
]


def rows(quick: bool = False):
    out = []
    for name, system, kw, backends in CASES:
        if quick and name == "ring_lattice_1024d4":
            continue
        comps = {b: get_backend(b).compile(system) for b in backends}
        for backend in backends:
            comp = comps[backend]
            explore(comp, backend=backend, **kw)  # warm compile
            t0 = time.perf_counter()
            res = explore(comp, backend=backend, **kw)
            dt = time.perf_counter() - t0
            us = dt * 1e6
            out.append((f"explore/{backend}/{name}", us / max(res.steps, 1),
                        f"{res.num_discovered}cfg@{res.steps}lvl,"
                        f"{res.num_discovered / dt:.0f}cfg/s"))
    return out
