"""Benchmark: LM substrate step times on reduced configs (CPU baseline).

Not TPU numbers — these keep the framework honest (catch regressions in
the train/serve step structure) and calibrate the per-arch smoke shapes.
TPU projections live in EXPERIMENTS.md §Roofline from the dry-run.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.models import init_cache, init_params
from repro.serve import make_decode_step, make_prefill_step
from repro.train import AdamWConfig, init_train_state, make_train_step

ARCHS = ["smollm-360m", "qwen2-moe-a2.7b", "jamba-1.5-large-398b",
         "rwkv6-7b", "minicpm3-4b", "musicgen-medium"]


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    out = []
    for name in ARCHS:
        cfg = reduced(get_config(name))
        params = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        batch = {k: jnp.asarray(v) for k, v in make_batch(
            cfg, DataConfig(), step=0, shard=0, batch=B,
            seq_len=S).items()}
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
        step = jax.jit(make_train_step(cfg, opt, remat="none"))
        state = init_train_state(params, opt)
        us = _time(lambda s: step(s, batch)[0], state)
        tokens = B * S
        out.append((f"train_step/{name}-smoke", us,
                    f"{tokens / us * 1e6:.0f}tok/s"))

        serve_batch = {k: v for k, v in batch.items() if k != "labels"}
        prefill = jax.jit(make_prefill_step(cfg, max_len=S + 8))
        us = _time(lambda p: prefill(p, serve_batch), params)
        out.append((f"prefill/{name}-smoke", us,
                    f"{tokens / us * 1e6:.0f}tok/s"))
    return out
