"""Benchmark: SNP transition step throughput vs. system size.

The paper's §5 evaluates simulation speed on one 3-neuron system; this
harness sweeps system size (the paper's future-work axis: "very large
systems with equally large matrices") and frontier width.  Every measured
path goes through the step-backend registry (`repro.core.backend`), so the
pure-jnp reference and the fused Pallas kernel (interpret mode on CPU —
kernel numbers are correctness+structure proxies, not TPU wall-times; TPU
projections come from the dry-run roofline) are benchmarked via one API,
and any future backend (sparse/CSR, ...) is picked up by name only.

Run as a module to emit ``BENCH_snp.json`` (step + tree rows):
``PYTHONPATH=src python -m benchmarks.bench_snp``.
"""

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile_system
from repro.core.backend import PallasBackend, get_backend
from repro.core.generators import random_system, scaled_pi

# Every registered backend is swept; pallas gets CPU-friendly blocks (the
# ops wrapper clamps them to the problem size anyway).
BACKENDS = (
    get_backend("ref"),
    PallasBackend(block_b=8, block_t=16, block_n=128),
)


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


@functools.partial(jax.jit, static_argnames=("max_branches", "backend"))
def _expand(cfgs, comp, max_branches, backend):
    out = backend.expand(cfgs, comp, max_branches)
    return out.configs, out.valid, out.emissions, out.overflow


def rows():
    out = []
    rng = np.random.default_rng(0)
    for m, rpn, B, T in [(3, 2, 64, 16), (30, 2, 64, 16),
                         (128, 2, 128, 32), (512, 2, 128, 32),
                         (2048, 2, 64, 32)]:
        system = (scaled_pi(m // 3) if m <= 30
                  else random_system(m, rpn, min(0.2, 8 / m), seed=1))
        comp = compile_system(system)
        cfgs = jnp.asarray(
            rng.integers(0, 4, size=(B, comp.num_neurons)), jnp.int32)
        us_ref = None  # first backend in the sweep is the baseline
        for backend in BACKENDS:
            if backend.name == "pallas" and comp.num_neurons > 512:
                continue  # interpret-mode emulation too slow at this size
            us = _time(_expand, cfgs, comp, T, backend)
            expansions = B * T
            derived = (f"{expansions / us:.1f}exp/us" if us_ref is None
                       else f"{us / us_ref:.1f}x_ref")
            if us_ref is None:
                us_ref = us
            out.append((f"snp_step/{backend.name}/m{comp.num_neurons}"
                        f"_n{comp.num_rules}_B{B}_T{T}", us, derived))
    return out


def main(path: str = "BENCH_snp.json") -> None:
    """Emit step- and tree-level rows for every backend as one JSON file."""
    from . import bench_tree

    payload = {
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows() + bench_tree.rows()
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(payload['rows'])} rows)")


if __name__ == "__main__":
    main()
