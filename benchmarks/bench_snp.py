"""Benchmark: SNP transition step throughput vs. system size.

The paper's §5 evaluates simulation speed on one 3-neuron system; this
harness sweeps system size (the paper's future-work axis: "very large
systems with equally large matrices") and frontier width, comparing the
pure-jnp reference semantics against the fused Pallas kernel (interpret
mode on CPU — kernel numbers are correctness+structure proxies, not TPU
wall-times; TPU projections come from the dry-run roofline).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile_system
from repro.core.generators import random_system, scaled_pi
from repro.kernels.snp_step import snp_step, snp_step_ref


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rows():
    out = []
    rng = np.random.default_rng(0)
    for m, rpn, B, T in [(3, 2, 64, 16), (30, 2, 64, 16),
                         (128, 2, 128, 32), (512, 2, 128, 32),
                         (2048, 2, 64, 32)]:
        system = (scaled_pi(m // 3) if m <= 30
                  else random_system(m, rpn, min(0.2, 8 / m), seed=1))
        comp = compile_system(system)
        cfgs = jnp.asarray(
            rng.integers(0, 4, size=(B, comp.num_neurons)), jnp.int32)
        us_ref = _time(snp_step_ref, cfgs, comp, T)
        expansions = B * T
        out.append((f"snp_step_ref/m{comp.num_neurons}_n{comp.num_rules}"
                    f"_B{B}_T{T}", us_ref,
                    f"{expansions / us_ref:.1f}exp/us"))
        if comp.num_neurons <= 512:
            us_k = _time(snp_step, cfgs, comp, max_branches=T,
                         block_b=8, block_t=16, block_n=128)
            out.append((f"snp_step_pallas/m{comp.num_neurons}"
                        f"_n{comp.num_rules}_B{B}_T{T}", us_k,
                        f"interp={us_k / us_ref:.1f}x_ref"))
    return out
