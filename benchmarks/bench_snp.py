"""Benchmark: SNP transition step throughput vs. system size.

The paper's §5 evaluates simulation speed on one 3-neuron system; this
harness sweeps system size (the paper's future-work axis: "very large
systems with equally large matrices") and frontier width.  Every measured
path goes through the step-backend registry (`repro.core.backend`), so the
pure-jnp reference, the fused Pallas kernel (interpret mode on CPU —
kernel numbers are correctness+structure proxies, not TPU wall-times; TPU
projections come from the dry-run roofline) and the sparse ELL backends
are benchmarked via one API, and any future backend is picked up by name
only.

Three tiers:

* the **standard sweep** (m <= 2048, Erdős–Rényi/scaled-Π systems) runs
  the dense baselines and the sparse backends side by side;
* the **large tier** (m in {2048, 8192, 32768}, bounded-degree
  ring-lattice/torus/power-law topologies) is where the dense ``O(B·T·n·m)``
  backends stop being runnable: ``m=8192`` already means a 0.5 GB dense
  ``M_Π`` and ~0.5 TFLOP per expansion, so dense rows are not attempted
  past the 2048 cross-over point and the sparse ``O(B·T·nnz)`` path sweeps
  alone (EXPERIMENTS.md §Sparse);
* the **hybrid tier** (power-law *without* ``max_in``, m up to 32768) is
  the heavy-tailed stress for the plan layer: pure ELL pads every
  in-adjacency row to the top hub's in-degree (and unrolls that many
  gathers per step), the hybrid ELL+COO plan
  (:class:`repro.core.plan.SystemPlan`) caps the ELL part at the auto hub
  threshold and segment-sums the tail.  Pure ELL is measured only at the
  smallest size — past it the hub width is the bottleneck and hybrid
  sweeps alone, mirroring the dense/sparse split above
  (EXPERIMENTS.md §Hybrid);
* the **hybrid-kernel tier** (same heavy-tailed family, m in
  {512, 2048, 8192}) compares the jnp ``sparse`` step against the fused
  ``sparse_pallas`` kernel **on hybrid plans** — the path the kernel
  lowering layer lifted (the in-kernel COO segment-sum stage, DESIGN.md
  §3 "Kernel lowering").  On CPU the kernel runs interpret mode, so rows
  are structure/correctness proxies, not TPU wall-times
  (EXPERIMENTS.md §Hybrid-kernel).
* the **delays tier** prices the second semantics tier: the same
  topology stepped under the paper's delay-free transition and under the
  delayed transition (3m-wide ``[spikes | countdown | pending]`` state,
  reopen fan-out, gated reception — DESIGN.md "Delayed semantics") at
  m in {512, 2048}, so the per-row ``x_no_delays`` factor is the cost of
  turning delays on for that backend (EXPERIMENTS.md §Delays);
* the **auto tier** replays the standard-sweep shapes and scores the
  query planner (``SystemPlan.for_system(mode="auto")``,
  ``repro.core.autotune``) against the fixed backends: per shape it
  emits the planner's pick (``auto/auto/...``), the fastest fixed
  backend (``auto/best/...``) and the slowest (``auto/worst/...``), all
  measured in the same process so ``tools/check_bench.py`` can enforce
  "auto stays within ``--auto-factor`` of best" without cross-hardware
  noise (EXPERIMENTS.md §Autotune).

Run as a module to emit ``BENCH_snp.json`` (step + tree rows):
``PYTHONPATH=src python -m benchmarks.bench_snp`` (``--quick`` for the
reduced CI smoke sweep).
"""

import argparse
import functools
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import (PallasBackend, SparsePallasBackend,
                                get_backend, resolve_kernel)
from repro.core.generators import (power_law, random_system, ring_lattice,
                                   scaled_pi, torus, with_delays)
from repro.core.plan import SystemPlan

# Every registered backend family is swept; the kernel backends get
# CPU-friendly blocks (the ops wrappers clamp them to the problem anyway).
BACKENDS = (
    get_backend("ref"),
    PallasBackend(block_b=8, block_t=16, block_n=128),
    get_backend("sparse"),
    SparsePallasBackend(block_b=8, block_t=16),
)

# Interpret-mode kernel emulation is too slow to sweep at scale on CPU.
_MAX_M = {"pallas": 512, "sparse_pallas": 128}


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


@functools.partial(jax.jit, static_argnames=("max_branches", "backend"))
def _expand(cfgs, comp, max_branches, backend):
    out = backend.expand(cfgs, comp, max_branches)
    return out.configs, out.valid, out.emissions, out.overflow


def _sweep(tag, system, B, T, backends, rng, reps, plan=None,
           rate_unit="us"):
    """One (system, B, T) point across ``backends``; the first backend in
    the list is the relative baseline for the rest.  ``plan`` compiles
    every backend under the same :class:`SystemPlan`; ``rate_unit="ms"``
    reports the baseline throughput per ms (for tiers whose call times
    would round exp/us to 0)."""
    out = []
    cfgs = None
    us_ref = None
    scale = 1e3 if rate_unit == "ms" else 1.0
    for backend in backends:
        comp = backend.compile(system, plan=plan)
        if cfgs is None:
            cfgs = jnp.asarray(
                rng.integers(0, 4, size=(B, comp.num_neurons)), jnp.int32)
        us = _time(_expand, cfgs, comp, T, backend, reps=reps)
        derived = (f"{B * T / us * scale:.1f}exp/{rate_unit}"
                   if us_ref is None
                   else f"{us / us_ref:.2f}x_{backends[0].name}")
        if us_ref is None:
            us_ref = us
        out.append((f"{tag}/{backend.name}/m{comp.num_neurons}"
                    f"_n{comp.num_rules}_B{B}_T{T}", us, derived))
    return out


def rows(quick: bool = False):
    reps = 2 if quick else 5
    out = []
    rng = np.random.default_rng(0)
    for m, rpn, B, T in [(3, 2, 64, 16), (30, 2, 64, 16),
                         (128, 2, 128, 32), (512, 2, 128, 32),
                         (2048, 2, 64, 32)]:
        system = (scaled_pi(m // 3) if m <= 30
                  else random_system(m, rpn, min(0.2, 8 / m), seed=1))
        backends = [b for b in BACKENDS if m <= _MAX_M.get(b.name, 1 << 30)]
        out += _sweep("snp_step", system, B, T, backends, rng, reps)
    return out


def large_rows(quick: bool = False):
    """Bounded-degree large-system tier.  Dense backends are measured only
    at the m=2048 cross-over; past that the dense encoding itself is the
    bottleneck (0.5 GB+ of M_Π) and only the sparse path is attempted."""
    reps = 2 if quick else 3
    cases = [
        ("torus", torus(32, 64, seed=2), 64, 32, ("ref", "sparse")),
        ("ring_lattice", ring_lattice(8192, 8, seed=2), 16, 16, ("sparse",)),
    ]
    if not quick:
        cases.append(("power_law",
                      power_law(32768, 4, seed=2, max_in=64),
                      8, 8, ("sparse",)))
    rng = np.random.default_rng(1)
    out = []
    for tag, system, B, T, names in cases:
        backends = [get_backend(n) for n in names]
        out += _sweep(f"snp_step_large/{tag}", system, B, T, backends, rng,
                      reps)
    return out


def hybrid_rows(quick: bool = False):
    """Heavy-tail tier: unbounded power-law hubs, ELL vs hybrid plan.

    Derived fields: the ``ell`` row is the 1.0x baseline where both run;
    every hybrid row also reports ``padX.XXx`` — its total in-adjacency
    slots (ELL padding + COO tail) relative to the pure-ELL layout of the
    same graph, the memory quantity the plan minimizes."""
    reps = 2 if quick else 3
    sizes = ((512, 32, 16),) if quick else \
        ((512, 32, 16), (2048, 16, 16), (8192, 8, 8), (32768, 8, 8))
    # The pure-ELL step unrolls Kin gathers and the unbounded hub's Kin
    # grows with m (~212 already at m=512): past 512 the ELL baseline is
    # the bottleneck being demonstrated, so hybrid sweeps alone there.
    ell_max_m = 512
    sp = get_backend("sparse")
    rng = np.random.default_rng(3)
    out = []
    for m, B, T in sizes:
        system = power_law(m, 4, seed=2)            # no max_in: real hubs
        plan = SystemPlan.for_system(system)
        comp_h = sp.compile(system, plan=plan)
        # Pure-ELL slot count is analytic (m rows padded to the hub
        # in-degree): at m=32768 the hub is ~4.7k wide, so actually
        # compiling that encoding would allocate ~0.6 GB of padding just
        # to read one number — only compile it where it is timed.
        in_deg = np.bincount(
            np.asarray(system.synapses)[:, 1], minlength=m)
        ell_slots = m * max(1, int(in_deg.max()))
        pad = comp_h.in_adjacency_slots / ell_slots
        cfgs = jnp.asarray(rng.integers(0, 4, size=(B, m)), jnp.int32)
        us_e = None
        if m <= ell_max_m:
            comp_e = sp.compile(system)             # pure ELL
            assert comp_e.in_adjacency_slots == ell_slots
            us_e = _time(_expand, cfgs, comp_e, T, sp, reps=reps)
            out.append((f"hybrid/power_law/ell/m{m}_Kin"
                        f"{comp_e.max_in_degree}_B{B}_T{T}", us_e,
                        f"{B * T / us_e:.1f}exp/us"))
        us_h = _time(_expand, cfgs, comp_h, T, sp, reps=reps)
        rel = "ell n/a" if us_e is None else f"{us_h / us_e:.2f}x_ell"
        out.append((f"hybrid/power_law/hybrid/m{m}_Kin"
                    f"{comp_h.max_in_degree}_B{B}_T{T}", us_h,
                    f"{rel},pad{pad:.2f}x"))
    return out


def hybrid_kernel_rows(quick: bool = False):
    """Hybrid-plan kernel tier: ``sparse`` (baseline) vs ``sparse_pallas``
    on the same hybrid ELL+COO compilation — the in-kernel COO stage the
    lowering layer added.  Interpret mode on CPU (structure proxy; the
    TPU story is the ROADMAP validation item)."""
    reps = 2 if quick else 3
    sizes = ((512, 8, 8),) if quick else \
        ((512, 8, 8), (2048, 8, 8), (8192, 4, 8))
    backends = (get_backend("sparse"), SparsePallasBackend(block_b=4,
                                                           block_t=8))
    rng = np.random.default_rng(5)
    out = []
    for m, B, T in sizes:
        system = power_law(m, 4, seed=2)            # no max_in: real hubs
        plan = SystemPlan.for_system(system)
        assert plan.encoding == "hybrid"
        out += _sweep("hybrid_kernel/power_law", system, B, T, backends,
                      rng, reps, plan=plan, rate_unit="ms")
    return out


def delays_rows(quick: bool = False):
    """Semantics tier: delayed vs delay-free step cost on one topology.

    Per backend and size, the ``no_delays`` row is the baseline (the
    paper's transition on the plain system) and the ``delays`` row steps
    the same topology with mixed per-rule delays (``d = k mod 3``) under
    the 3m-wide delayed state; its derived field is the delayed/plain
    ratio — the price of the countdown/pending bookkeeping, the reopen
    fan-out matmul (dense) / second rank table (sparse) and the gated
    reception.  Only the jnp backends sweep here: the interpret-mode
    kernels are correctness proxies (their delayed stages are covered by
    the equivalence matrix in tests/), not wall-times worth charting."""
    reps = 2 if quick else 3
    sizes = ((512, 64, 16),) if quick else ((512, 64, 16), (2048, 32, 16))
    plans = {"ref": "dense", "sparse": "ell"}
    rng = np.random.default_rng(11)
    out = []
    for m, B, T in sizes:
        base = random_system(m, 2, min(0.2, 8 / m), seed=1)
        sysd = with_delays(base, lambda k, r: k % 3)
        spikes = rng.integers(0, 4, size=(B, m))
        cfgs0 = jnp.asarray(spikes, jnp.int32)
        cfgsd = jnp.asarray(
            np.concatenate([spikes, np.zeros((B, 2 * m), np.int64)], axis=1),
            jnp.int32)
        for name, enc in plans.items():
            be = get_backend(name)
            us0 = _time(_expand, cfgs0, be.compile(base), T, be, reps=reps)
            out.append((f"delays/{name}/no_delays/m{m}_B{B}_T{T}", us0,
                        f"{B * T / us0 * 1e3:.1f}exp/ms"))
            compd = be.compile(
                sysd, plan=SystemPlan(encoding=enc, semantics="delays"))
            usd = _time(_expand, cfgsd, compd, T, be, reps=reps)
            out.append((f"delays/{name}/delays/m{m}_B{B}_T{T}", usd,
                        f"{usd / us0:.2f}x_no_delays"))
    return out


def explore_rows(quick: bool = False):
    """Explore dedup tier: end-to-end BFS throughput, sorted vs hash
    visited set (DESIGN.md §2).

    The legacy dedup re-sorts the full capacity-``V`` visited arrays
    every wave (``O((V+C)·log(V+C))`` regardless of how few slots are
    occupied); the hash table probes only the ``C`` wave candidates
    (``O(C·probe)``).  Per (system, caps) point the ``sorted`` row is the
    baseline and the ``hash`` row's derived field is the waves/sec
    speedup; both report ``syncsN`` — the number of host↔device round
    trips the whole run performed (the fused ``lax.while_loop`` drivers
    make exactly one dispatch when not checkpointing).  ``counter`` is
    the dedup-bound extreme (one new config per wave, deep BFS);
    unbounded power-law adds expansion cost at m in {512, 2048, 8192}.
    The ``explore/partition`` rows price the degree-weighted LPT
    assignment against the contiguous slicing and report the resulting
    per-shard degree-load stats (EXPERIMENTS.md §Explore)."""
    from repro.core.engine import explore
    from repro.core.generators import counter
    from repro.core.plan import partition_neurons, partition_stats
    from repro.runtime.faults import FaultInjector

    reps = 1 if quick else 3
    sp = get_backend("sparse")
    cases = [("counter", counter(12), "ref", None,
              dict(max_steps=96, frontier_cap=16, visited_cap=16384,
                   max_branches=8))]
    sizes = (512,) if quick else (512, 2048, 8192)
    for m in sizes:
        system = power_law(m, 4, seed=2)            # no max_in: real hubs
        cases.append(("power_law", system, sp, SystemPlan.for_system(system),
                      dict(max_steps=8, frontier_cap=16,
                           visited_cap=65536, max_branches=8)))
    out = []
    for tag, system, backend, plan, kw in cases:
        us_sorted = None
        for dedup in ("sorted", "hash"):
            arg = "sort" if dedup == "sorted" else "hash"
            explore(system, dedup=arg, backend=backend, plan=plan,
                    **kw)                            # compile
            inj = FaultInjector()
            t0 = time.perf_counter()
            for _ in range(reps):
                r = explore(system, dedup=arg, backend=backend, plan=plan,
                            fault_injector=inj, **kw)
            us = (time.perf_counter() - t0) / reps * 1e6
            syncs = inj.calls // reps
            rate = max(r.steps, 1) / (us / 1e6)
            name = (f"explore/{tag}/{dedup}/m{system.num_neurons}"
                    f"_F{kw['frontier_cap']}_T{kw['max_branches']}"
                    f"_V{kw['visited_cap']}")
            derived = (f"{rate:.1f}waves/s,syncs{syncs}"
                       if us_sorted is None
                       else f"{us_sorted / us:.2f}x_sorted,syncs{syncs}")
            if us_sorted is None:
                us_sorted = us
            out.append((name, us, derived))
    # degree-weighted vs contiguous shard assignment: cost of the
    # partition itself + the per-shard degree-load stats it buys
    psys = power_law(sizes[-1], 4, seed=2)
    for part in ("contiguous", "degree"):
        t0 = time.perf_counter()
        *_, occ = partition_neurons(psys, 8, part)
        us = (time.perf_counter() - t0) * 1e6
        st = partition_stats(occ)
        out.append((f"explore/partition/{part}/m{psys.num_neurons}_S8",
                    us, f"occ_max{st['max']:.0f},imb{st['imbalance']:.2f}"))
    return out


def auto_rows(quick: bool = False):
    """Planner tier: what ``mode="auto"`` actually costs vs a fixed
    backend choice, at the standard-sweep shapes.

    Per shape, every eligible fixed backend is timed once; the planner's
    pick is then resolved (``SystemPlan.for_system(workload=(B, T),
    mode="auto")`` + ``resolve_kernel``) and — whenever it lands on an
    already-measured fixed instance — *reuses* that measurement, so the
    ``auto``/``best`` ratio is free of re-measurement noise and is
    exactly 1.0 when the planner picks the per-shape winner.  The
    planner runs against an empty scratch cache (``REPRO_AUTOTUNE_CACHE``
    is pointed at a fresh temp file) so rows reflect the committed
    seed → model → heuristic flow, not whatever a developer's personal
    cache happens to hold."""
    reps = 2 if quick else 5
    rng = np.random.default_rng(7)
    shapes = [(3, 2, 64, 16), (30, 2, 64, 16), (128, 2, 128, 32)]
    if not quick:
        shapes += [(512, 2, 128, 32), (2048, 2, 64, 32)]
    scratch = os.path.join(tempfile.mkdtemp(prefix="repro-bench-"),
                           "autotune.json")
    prev = os.environ.get("REPRO_AUTOTUNE_CACHE")
    os.environ["REPRO_AUTOTUNE_CACHE"] = scratch
    out = []
    try:
        for m, rpn, B, T in shapes:
            system = (scaled_pi(m // 3) if m <= 30
                      else random_system(m, rpn, min(0.2, 8 / m), seed=1))
            eligible = [b for b in BACKENDS
                        if m <= _MAX_M.get(b.name, 1 << 30)]
            cfgs = None
            fixed = {}
            for backend in eligible:
                comp = backend.compile(system)
                if cfgs is None:
                    cfgs = jnp.asarray(
                        rng.integers(0, 4, size=(B, comp.num_neurons)),
                        jnp.int32)
                    shape = (f"m{comp.num_neurons}_n{comp.num_rules}"
                             f"_B{B}_T{T}")
                fixed[backend] = _time(_expand, cfgs, comp, T, backend,
                                       reps=reps)
            plan = SystemPlan.for_system(system, workload=(B, T),
                                         mode="auto")
            name = plan.backend or ("sparse" if plan.encoding in
                                    ("ell", "hybrid") else "ref")
            be = resolve_kernel(get_backend(name), plan)
            if be in fixed:
                us_auto = fixed[be]
            else:
                comp = be.compile(system, plan=plan)
                us_auto = _time(_expand, cfgs, comp, T, be, reps=reps)
            (b_best, us_best), (b_worst, us_worst) = (
                min(fixed.items(), key=lambda kv: kv[1]),
                max(fixed.items(), key=lambda kv: kv[1]))
            out += [
                (f"auto/auto/{shape}", us_auto,
                 f"{be.name},{us_auto / us_best:.2f}x_best"),
                (f"auto/best/{shape}", us_best, b_best.name),
                (f"auto/worst/{shape}", us_worst,
                 f"{b_worst.name},{us_worst / us_best:.2f}x_best"),
            ]
    finally:
        if prev is None:
            os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
        else:
            os.environ["REPRO_AUTOTUNE_CACHE"] = prev
    return out


def main(path: str = "BENCH_snp.json", quick: bool = False) -> None:
    """Emit step- and tree-level rows for every backend as one JSON file."""
    from . import bench_tree

    payload = {
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in (rows(quick) + large_rows(quick)
                                      + hybrid_rows(quick)
                                      + hybrid_kernel_rows(quick)
                                      + delays_rows(quick)
                                      + explore_rows(quick)
                                      + auto_rows(quick)
                                      + bench_tree.rows(quick))
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(payload['rows'])} rows)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI smoke runs")
    ap.add_argument("--out", default="BENCH_snp.json")
    args = ap.parse_args()
    main(args.out, quick=args.quick)
