"""Benchmark harness.  One module per paper table/figure:

* bench_snp   — transition-step throughput vs system size (paper §5
  timing): the standard sweep plus the large (bounded-degree), hybrid
  (heavy-tailed power-law, ELL vs hybrid plan) and hybrid-kernel
  (sparse vs sparse_pallas on hybrid plans) tiers
* bench_tree  — full computation-tree exploration (paper §5 run / Fig. 4)
* bench_serve — trace-serving front end: sync/async/mesh (EXPERIMENTS.md
  §Serving)
* bench_lm    — LM substrate step times (framework baseline)

Prints ``name,us_per_call,derived`` CSV; ``--quick`` runs every tier's
reduced CI smoke sweep.  Roofline-based TPU projections are produced by
the dry-run (src/repro/launch/dryrun.py), not here.
"""

import argparse
import sys


def main(quick: bool = False) -> None:
    from . import bench_lm, bench_paper_mode, bench_serve, bench_snp, \
        bench_tree

    sweeps = [
        lambda: bench_snp.rows(quick),
        lambda: bench_snp.large_rows(quick),
        lambda: bench_snp.hybrid_rows(quick),
        lambda: bench_snp.hybrid_kernel_rows(quick),
        lambda: bench_tree.rows(quick),
        lambda: bench_serve.rows(quick),
        lambda: bench_paper_mode.rows(),
        lambda: bench_lm.rows(),
    ]
    print("name,us_per_call,derived")
    for sweep in sweeps:
        for name, us, derived in sweep():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI smoke runs")
    main(quick=ap.parse_args().quick)
