"""Benchmark harness.  One module per paper table/figure:

* bench_snp   — transition-step throughput vs system size (paper §5 timing)
* bench_tree  — full computation-tree exploration (paper §5 run / Fig. 4)
* bench_serve — trace-serving front end: sync/async/mesh (EXPERIMENTS.md
  §Serving)
* bench_lm    — LM substrate step times (framework baseline)

Prints ``name,us_per_call,derived`` CSV.  Roofline-based TPU projections
are produced by the dry-run (src/repro/launch/dryrun.py), not here.
"""

import sys


def main() -> None:
    from . import bench_lm, bench_paper_mode, bench_serve, bench_snp, bench_tree

    print("name,us_per_call,derived")
    for mod in (bench_snp, bench_tree, bench_serve, bench_paper_mode, bench_lm):
        for name, us, derived in mod.rows():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
