"""Benchmark: the paper's own algorithm vs. this framework's engine.

The 2011 simulator enumerates spiking vectors on the HOST (Python string
concatenation, Algorithm 2) and ships one ``S_k · M`` vector-matrix product
at a time to the device.  ``paper_mode_step`` reimplements that faithfully
(strings and all); ``explore`` is our batched rank-decode engine.  The
ratio is the reproduction -> beyond-paper speedup reported in
EXPERIMENTS.md §Perf (CPU numbers; the architectural gap only widens on a
real accelerator, where per-vector host round-trips dominate).
"""

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile_system, explore, paper_pi
from repro.core.generators import random_system, scaled_pi
from repro.core.system import SNPSystem


def _paper_applicable(spikes: int, base: int, covering: bool,
                      period: int) -> bool:
    if spikes < base:
        return False
    if covering:
        return True
    if period > 0:
        return (spikes - base) % period == 0
    return spikes == base


@jax.jit
def _device_svm(s_vec, m_mat, c_vec):
    # the paper's device step: one spiking vector times M, plus C_k
    return c_vec + s_vec @ m_mat


def paper_mode_explore(system: SNPSystem, max_steps: int,
                       max_configs: int = 100000):
    """Algorithm 1+2 as published: host strings enumerate S_k; the device
    multiplies one vector at a time."""
    comp = compile_system(system)
    m_mat = comp.M.astype(jnp.float32)
    rules = [system.rules[i] for i in comp.rule_order]
    seen = {tuple(system.initial_spikes)}
    frontier = [tuple(system.initial_spikes)]
    for _ in range(max_steps):
        nxt = []
        for cfg in frontier:
            # II-1/II-2: per-neuron {1,0} strings for applicable rules
            per_neuron = []
            for ni in range(system.num_neurons):
                idxs = [i for i, r in enumerate(rules) if r.neuron == ni]
                apps = [i for i in idxs if _paper_applicable(
                    cfg[ni], rules[i].regex_base, rules[i].covering,
                    rules[i].regex_period)]
                strings = []
                for a in apps:
                    s = ["0"] * len(idxs)
                    s[idxs.index(a)] = "1"
                    strings.append("".join(s))
                per_neuron.append(strings if strings
                                  else ["0" * len(idxs)] if idxs else [""])
            if all(set(p) == {"0" * len(p[0])} or p == [""]
                   for p in per_neuron):
                continue
            # II-3: exhaustive pairwise concatenation -> tmp3
            tmp3 = [""]
            for strings in per_neuron:
                tmp3 = [a + b for a in tmp3 for b in strings]
            # device: one vector-matrix product per spiking vector
            c_vec = jnp.asarray(cfg, jnp.float32)
            for s_str in tmp3:
                s_vec = jnp.asarray([int(ch) for ch in s_str], jnp.float32)
                new = tuple(int(v) for v in np.asarray(
                    _device_svm(s_vec, m_mat, c_vec)))
                if new not in seen:
                    seen.add(new)
                    nxt.append(new)
                    if len(seen) >= max_configs:
                        return seen
        frontier = nxt
        if not frontier:
            break
    return seen


def rows():
    out = []
    cases = [
        ("pi", paper_pi(True), 10, dict(frontier_cap=64, visited_cap=1024,
                                        max_branches=16)),
        ("pi_x3", scaled_pi(3), 4, dict(frontier_cap=256, visited_cap=8192,
                                        max_branches=64)),
        ("random_24n", random_system(24, 2, 0.15, seed=3), 5,
         dict(frontier_cap=256, visited_cap=8192, max_branches=64)),
    ]
    for name, system, steps, kw in cases:
        comp = compile_system(system)
        cap = 100000
        t0 = time.perf_counter()
        seen = paper_mode_explore(system, steps, max_configs=cap)
        t_paper = time.perf_counter() - t0

        explore(comp, max_steps=steps, **kw)  # warm compile
        t0 = time.perf_counter()
        res = explore(comp, max_steps=steps, **kw)
        t_ours = time.perf_counter() - t0

        mine = {tuple(int(v) for v in row) for row in res.configs}
        capped = len(seen) >= cap
        overflow = (res.branch_overflow or res.frontier_overflow
                    or res.visited_overflow)
        if capped or overflow:
            # caps/overflow make raw set equality meaningless; soundness:
            # whichever explored less must be contained in the other
            small, big = (mine, seen) if overflow else (seen, mine)
            agree = f"subset={small <= big or capped}"
        else:
            agree = f"equal={seen == mine}"
        out.append((f"paper_mode/{name}", t_paper * 1e6,
                    f"paper={len(seen)}cfg engine={len(mine)}cfg {agree}"))
        out.append((f"batched_engine/{name}", t_ours * 1e6,
                    f"speedup={t_paper / max(t_ours, 1e-9):.1f}x"))
    return out
