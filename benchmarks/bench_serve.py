"""Benchmark: the SNP trace-serving front end (sync vs async vs mesh).

Measures what the service adds on top of the raw ``run_traces`` scan
(EXPERIMENTS.md §Serving): grouping/padding overhead of a synchronous
``drain``, per-request completion latency (p50/p99) of the async
background-flush mode, and the mesh-sharded runner
(:func:`repro.core.distributed.run_traces_distributed`) on however many
devices are present — in single-device CI that row doubles as a shard_map
overhead measurement.

Every configuration is warmed first so the jit compile is excluded: the
service holds device shapes fixed (fixed batch, bucketed steps), so a
warmed cache is the steady state a long-lived service runs in.

A second tier, ``serve_fault/...``, measures the failure-domain machinery
(DESIGN.md §4.4): the same burst served under a deterministic
:class:`~repro.runtime.faults.FaultInjector` schedule (two transient flush
failures + one poison request) with a :class:`FaultPolicy` that retries
and bisects.  ``us_per_call`` is per *successfully served* trace — goodput
— so the row directly prices what recovery costs versus the fault-free
``serve/...`` row of the same shape.

Rows merge into ``BENCH_snp.json`` (names ``serve/...`` and
``serve_fault/...``) next to the step and tree tiers:
``PYTHONPATH=src:. python -m benchmarks.bench_serve`` (``--quick`` for the
CI smoke sweep).
"""

import argparse
import json
import os
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import compile_system, paper_pi
from repro.runtime import FaultInjector, FaultPolicy, PoisonError
from repro.serve import SNPTraceService, TraceRequest, make_trace_runner


def _requests(system, n, steps):
    return [TraceRequest(system, steps=steps, policy="random", seed=s)
            for s in range(n)]


def _bench_sync(system, n, steps, batch, runner=None, tag="sync"):
    svc = SNPTraceService(batch_size=batch, step_bucket=8, runner=runner)
    for r in _requests(system, batch, steps):   # warm the jit cache
        svc.submit(r)
    svc.drain()
    for r in _requests(system, n, steps):
        svc.submit(r)
    t0 = time.perf_counter()
    results = svc.drain()
    dt = time.perf_counter() - t0
    assert len(results) == n
    return (f"serve/{tag}/pi_N{n}_s{steps}_b{batch}", dt / n * 1e6,
            f"{n / dt:.0f}tr/s,{svc.num_device_calls - 1}calls")


def _bench_async(system, n, steps, batch, max_delay_ms):
    with SNPTraceService(batch_size=batch, step_bucket=8, async_mode=True,
                         max_delay_ms=max_delay_ms) as warm:
        [f.result() for f in
         [warm.submit(r) for r in _requests(system, batch, steps)]]
    done = {}
    with SNPTraceService(batch_size=batch, step_bucket=8, async_mode=True,
                         max_delay_ms=max_delay_ms) as svc:
        t0 = time.perf_counter()
        futs = []
        for i, r in enumerate(_requests(system, n, steps)):
            fut = svc.submit(r)
            fut.add_done_callback(
                lambda f, i=i: done.setdefault(i, time.perf_counter()))
            futs.append(fut)
        for f in futs:
            f.result()
        dt = time.perf_counter() - t0
    lat_ms = np.asarray([done[i] - t0 for i in range(n)]) * 1e3
    return (f"serve/async/pi_N{n}_s{steps}_b{batch}_d{max_delay_ms:g}ms",
            dt / n * 1e6,
            f"{n / dt:.0f}tr/s,p50={np.percentile(lat_ms, 50):.0f}ms,"
            f"p99={np.percentile(lat_ms, 99):.0f}ms")


def _fault_schedule(n):
    """The PR's acceptance schedule scaled to the burst: two transient
    flush failures (the first on the burst's first flush, so the retry
    path is on the clock; the second mid-bisection) + one poison request
    (a nonzero seed mid-burst)."""
    poison = n // 2 + 1
    inj = FaultInjector(fail_calls=(1, 4), poison_seeds=(poison,))
    pol = FaultPolicy(max_retries=2, backoff_ms=0.0, bisect=True,
                      degrade=False)
    return inj, pol, poison


def _fault_derived(svc, served, n, dt):
    s = svc.stats()
    return (f"{served / dt:.0f}tr/s,goodput={served}/{n},"
            f"retries={s['retries']},bisects={s['bisections']},"
            f"failed_calls={s['failed_calls']}")


def _bench_fault_sync(system, n, steps, batch):
    warm = SNPTraceService(batch_size=batch, step_bucket=8)
    for r in _requests(system, batch, steps):   # warm the global jit cache
        warm.submit(r)                          # fault-free so the measured
    warm.drain()                                # run sees the whole schedule
    inj, pol, _ = _fault_schedule(n)
    svc = SNPTraceService(batch_size=batch, step_bucket=8,
                          policy=pol, fault_injector=inj)
    for r in _requests(system, n, steps):
        svc.submit(r)
    t0 = time.perf_counter()
    results = svc.drain()
    dt = time.perf_counter() - t0
    assert len(results) == n - 1                # exactly the poison failed
    assert all(isinstance(e, PoisonError)
               for e in svc.last_failures.values())
    return (f"serve_fault/sync/pi_N{n}_s{steps}_b{batch}",
            dt / len(results) * 1e6, _fault_derived(svc, len(results), n, dt))


def _bench_fault_async(system, n, steps, batch, max_delay_ms):
    with SNPTraceService(batch_size=batch, step_bucket=8, async_mode=True,
                         max_delay_ms=max_delay_ms) as warm:
        [f.result() for f in
         [warm.submit(r) for r in _requests(system, batch, steps)]]
    inj, pol, _ = _fault_schedule(n)
    with SNPTraceService(batch_size=batch, step_bucket=8, async_mode=True,
                         max_delay_ms=max_delay_ms,
                         policy=pol, fault_injector=inj) as svc:
        t0 = time.perf_counter()
        futs = [svc.submit(r) for r in _requests(system, n, steps)]
        served = failed = 0
        for f in futs:
            try:
                f.result()
                served += 1
            except Exception:
                failed += 1
        dt = time.perf_counter() - t0
        assert failed == 1                      # exactly the poison failed
        row = (f"serve_fault/async/pi_N{n}_s{steps}_b{batch}"
               f"_d{max_delay_ms:g}ms",
               dt / served * 1e6, _fault_derived(svc, served, n, dt))
    return row


def rows(quick: bool = False):
    # pre-compiled so no mode pays host-side lowering inside its timed
    # window (the async measurement service is fresh and would otherwise
    # compile on its first submit, which the sync path does pre-t0)
    system = compile_system(paper_pi(covering=True))
    n = 64 if quick else 256
    steps = 32
    batch = 64 if quick else 256
    out = [
        _bench_sync(system, n, steps, batch),
        _bench_async(system, n, steps, batch, max_delay_ms=5.0),
        _bench_fault_sync(system, n, steps, batch),
        _bench_fault_async(system, n, steps, batch, max_delay_ms=5.0),
    ]
    # mesh-sharded runner over every available device (1 in plain CI; run
    # under XLA_FLAGS=--xla_force_host_platform_device_count=8 to measure
    # a faked multi-device mesh on CPU) — same 1-D layout the production
    # serving path flattens to (DESIGN.md §4.3)
    ndev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("traces",))
    out.append(_bench_sync(system, n, steps, batch,
                           runner=make_trace_runner(mesh=mesh),
                           tag=f"mesh{ndev}"))
    return out


def main(path: str = "BENCH_snp.json", quick: bool = False) -> None:
    """Merge serve rows into ``path``, preserving the other tiers."""
    payload = {"rows": []}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["rows"] = [r for r in payload.get("rows", [])
                       if not r["name"].startswith(("serve/", "serve_fault/"))]
    payload["rows"] += [
        {"name": name, "us_per_call": us, "derived": derived}
        for name, us, derived in rows(quick)
    ]
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(payload['rows'])} rows)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI smoke runs")
    ap.add_argument("--out", default="BENCH_snp.json")
    args = ap.parse_args()
    main(args.out, quick=args.quick)
