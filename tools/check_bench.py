#!/usr/bin/env python3
"""Guard the benchmark surface: fail if ``BENCH_snp.json`` silently loses
a tier or a backend key relative to a baseline.

Benchmarks are regenerated per PR (the CI smoke sweep overwrites the
file), which makes it easy for a refactor to drop a whole tier — the rows
just stop being emitted and nobody notices until the perf trajectory has
a hole.  This check compares the *key structure* (never the timings):

* a **tier** is the first ``/``-segment of a row name (``snp_step``,
  ``snp_step_large``, ``hybrid``, ``explore``, ``serve``, ...);
* a **backend/mode key** is any later segment from the known vocabulary
  (step-backend registry names, plan encodings, serve modes; ``meshN``
  normalizes to ``mesh`` so the faked device count can vary).

Every (tier, key) pair present in the baseline must be present in the
candidate; new pairs are always fine.  Timings may drift, coverage may
only grow.

Usage::

    python tools/check_bench.py [BASELINE] [CANDIDATE]

Defaults: baseline = ``git show HEAD:BENCH_snp.json`` (so a working-tree
regeneration is checked against the committed file), candidate =
``BENCH_snp.json``.  CI snapshots the checked-out file before running the
smoke sweep and passes it explicitly.
"""

from __future__ import annotations

import json
import re
import subprocess
import sys

KNOWN_KEYS = {
    # step-backend registry names
    "ref", "pallas", "sparse", "sparse_pallas",
    # plan encodings (hybrid tier)
    "ell", "hybrid",
    # serve modes ("meshN" is normalized separately)
    "sync", "async",
}
_MESH = re.compile(r"^mesh\d+$")


def row_keys(payload: dict) -> set:
    """(tier,) and (tier, key) pairs of every row name."""
    keys = set()
    for row in payload.get("rows", []):
        parts = str(row.get("name", "")).split("/")
        if not parts or not parts[0]:
            continue
        tier = parts[0]
        keys.add((tier,))
        for part in parts[1:]:
            if _MESH.match(part):
                keys.add((tier, "mesh"))
            elif part in KNOWN_KEYS:
                keys.add((tier, part))
    return keys


def _load(path: str) -> dict:
    if path.startswith("git:"):
        out = subprocess.run(
            ["git", "show", path[len("git:"):]],
            capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    with open(path) as f:
        return json.load(f)


def main(argv: list) -> int:
    baseline = argv[1] if len(argv) > 1 else "git:HEAD:BENCH_snp.json"
    candidate = argv[2] if len(argv) > 2 else "BENCH_snp.json"
    base = _load(baseline)
    cand = _load(candidate)
    missing = sorted(row_keys(base) - row_keys(cand))
    if missing:
        print(f"check_bench: {candidate} lost {len(missing)} benchmark "
              f"key(s) present in {baseline}:")
        for key in missing:
            print("  - " + "/".join(key))
        print("Re-emit the missing tier(s) (benchmarks/bench_snp.py, "
              "benchmarks/bench_serve.py) or, if a tier was retired on "
              "purpose, update the committed BENCH_snp.json in the same "
              "change.")
        return 1
    print(f"check_bench: OK — {len(row_keys(cand))} keys cover the "
          f"{len(row_keys(base))} baseline keys")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
