#!/usr/bin/env python3
"""Guard the benchmark surface: fail if ``BENCH_snp.json`` silently loses
a tier or a backend key relative to a baseline, or if a tier's backend
wall-times regress hard.

Benchmarks are regenerated per PR (the CI smoke sweep overwrites the
file), which makes it easy for a refactor to drop a whole tier — the rows
just stop being emitted and nobody notices until the perf trajectory has
a hole.  Two checks against a baseline:

1. **Structure** — the *key structure* (never the timings) may only grow:

   * a **tier** is the first ``/``-segment of a row name (``snp_step``,
     ``snp_step_large``, ``hybrid``, ``hybrid_kernel``, ``delays``,
     ``explore``, ``serve``, ``serve_fault``, ...);
   * a **backend/mode key** is any later segment from the known
     vocabulary (step-backend registry names, plan encodings, serve
     modes; ``meshN`` normalizes to ``mesh`` so the faked device count
     can vary).

   Every (tier, key) pair present in the baseline must be present in the
   candidate; new pairs are always fine.

2. **Regression** — for every (tier, key) pair, the median of the
   per-row ratios ``candidate_us / baseline_us`` over the *shared row
   names* must stay under ``--regress-factor`` (default 2.0).  Medians of
   name-matched ratios, so quick sweeps (fewer rows, same names) compare
   meaningfully; ``--no-regress-check`` is the escape hatch when the
   candidate is a ``--quick`` run on very different hardware than the
   committed baseline.

3. **Planner** — within the candidate alone, every planner-tier
   ``auto/auto/<shape>`` row must stay under ``--auto-factor`` (default
   1.2) × the same run's ``auto/best/<shape>`` row.  Both rows are
   measured in one bench process, so this check is hardware-independent
   and runs even under ``--no-regress-check``.

Usage::

    python tools/check_bench.py [BASELINE] [CANDIDATE]
        [--regress-factor 2.0] [--no-regress-check] [--auto-factor 1.2]

Defaults: baseline = ``git show HEAD:BENCH_snp.json`` (so a working-tree
regeneration is checked against the committed file), candidate =
``BENCH_snp.json``.  CI snapshots the checked-out file before running the
smoke sweep and passes it explicitly.
"""

from __future__ import annotations

import argparse
import json
import re
import statistics
import subprocess
import sys

KNOWN_KEYS = {
    # step-backend registry names
    "ref", "pallas", "sparse", "sparse_pallas",
    # plan encodings (hybrid tier)
    "ell", "hybrid",
    # serve modes ("meshN" is normalized separately)
    "sync", "async",
    # semantics tiers (delays tier rows)
    "no_delays", "delays",
    # planner tier row kinds (auto tier)
    "auto", "best", "worst",
    # explore dedup tier (visited-set scheme) + shard partitions
    "sorted", "hash", "contiguous", "degree",
}
_MESH = re.compile(r"^mesh\d+$")


def _name_keys(name: str) -> set:
    """(tier,) and (tier, key) pairs of one row name."""
    parts = str(name).split("/")
    if not parts or not parts[0]:
        return set()
    tier = parts[0]
    keys = {(tier,)}
    for part in parts[1:]:
        if _MESH.match(part):
            keys.add((tier, "mesh"))
        elif part in KNOWN_KEYS:
            keys.add((tier, part))
    return keys


def row_keys(payload: dict) -> set:
    """(tier,) and (tier, key) pairs of every row name."""
    keys = set()
    for row in payload.get("rows", []):
        keys |= _name_keys(row.get("name", ""))
    return keys


def regression_failures(base: dict, cand: dict, factor: float) -> list:
    """[(tier/key, median_ratio, n_rows)] where the name-matched median
    ``cand/base`` timing ratio exceeds ``factor``."""
    def times(payload):
        return {str(r["name"]): float(r["us_per_call"])
                for r in payload.get("rows", [])
                if "name" in r and "us_per_call" in r}

    tb, tc = times(base), times(cand)
    ratios: dict = {}
    for name in tb.keys() & tc.keys():
        if tb[name] <= 0:
            continue
        for key in _name_keys(name):
            if len(key) == 2:  # only (tier, backend/mode) pairs
                ratios.setdefault(key, []).append(tc[name] / tb[name])
    out = []
    for key, rs in sorted(ratios.items()):
        med = statistics.median(rs)
        if med > factor:
            out.append(("/".join(key), med, len(rs)))
    return out


def auto_failures(cand: dict, factor: float) -> list:
    """[(shape, ratio)] where the planner tier's ``auto/auto/<shape>``
    row exceeds ``factor`` × the same run's ``auto/best/<shape>`` row.

    Both rows come from the *candidate* run (the bench harness measures
    them in one process), so this check is internal consistency — "the
    planner's pick stays within ``factor`` of the best fixed backend" —
    and is meaningful regardless of what hardware the baseline was
    measured on."""
    auto, best = {}, {}
    for row in cand.get("rows", []):
        parts = str(row.get("name", "")).split("/")
        if len(parts) == 3 and parts[0] == "auto":
            {"auto": auto, "best": best}.get(parts[1], {})[parts[2]] = \
                float(row["us_per_call"])
    out = []
    for shape in sorted(auto.keys() & best.keys()):
        if best[shape] > 0 and auto[shape] / best[shape] > factor:
            out.append((shape, auto[shape] / best[shape]))
    return out


def _load(path: str) -> dict:
    if path.startswith("git:"):
        out = subprocess.run(
            ["git", "show", path[len("git:"):]],
            capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    with open(path) as f:
        return json.load(f)


def main(argv: list) -> int:
    ap = argparse.ArgumentParser(
        description="Benchmark structure + regression guard")
    ap.add_argument("baseline", nargs="?", default="git:HEAD:BENCH_snp.json")
    ap.add_argument("candidate", nargs="?", default="BENCH_snp.json")
    ap.add_argument("--regress-factor", type=float, default=2.0,
                    help="fail when a (tier, backend) median timing ratio "
                         "exceeds this (default 2.0)")
    ap.add_argument("--no-regress-check", action="store_true",
                    help="structure check only — escape hatch for --quick "
                         "candidates measured on unlike hardware")
    ap.add_argument("--auto-factor", type=float, default=1.2,
                    help="fail when the planner tier's auto pick exceeds "
                         "this factor of the same run's best fixed backend "
                         "(default 1.2; same-run rows, so this check runs "
                         "even with --no-regress-check)")
    args = ap.parse_args(argv[1:])

    base = _load(args.baseline)
    cand = _load(args.candidate)
    missing = sorted(row_keys(base) - row_keys(cand))
    if missing:
        print(f"check_bench: {args.candidate} lost {len(missing)} benchmark "
              f"key(s) present in {args.baseline}:")
        for key in missing:
            print("  - " + "/".join(key))
        print("Re-emit the missing tier(s) (benchmarks/bench_snp.py, "
              "benchmarks/bench_serve.py) or, if a tier was retired on "
              "purpose, update the committed BENCH_snp.json in the same "
              "change.")
        return 1
    if not args.no_regress_check:
        regressed = regression_failures(base, cand, args.regress_factor)
        if regressed:
            print(f"check_bench: {args.candidate} regressed "
                  f"{len(regressed)} tier/backend median(s) more than "
                  f"{args.regress_factor:.1f}x vs {args.baseline}:")
            for key, med, n in regressed:
                print(f"  - {key}: median {med:.2f}x over {n} shared rows")
            print("Investigate the slowdown, or pass --no-regress-check "
                  "for a --quick candidate measured on unlike hardware.")
            return 1
    slow_auto = auto_failures(cand, args.auto_factor)
    if slow_auto:
        print(f"check_bench: the query planner's auto pick is more than "
              f"{args.auto_factor:.2f}x slower than the best fixed backend "
              f"at {len(slow_auto)} shape(s) (same-run rows):")
        for shape, ratio in slow_auto:
            print(f"  - {shape}: auto {ratio:.2f}x best")
        print("The planner is mis-picking: refresh its seeds by "
              "committing the regenerated BENCH_snp.json, or fix the "
              "cost model in src/repro/core/autotune.py.")
        return 1
    print(f"check_bench: OK — {len(row_keys(cand))} keys cover the "
          f"{len(row_keys(base))} baseline keys"
          + ("" if args.no_regress_check else
             f"; no tier/backend median regressed "
             f">{args.regress_factor:.1f}x"))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
