"""Markdown link checker for the repo docs (stdlib only; CI + tier-1).

Verifies every internal link in the given markdown files:

* relative file targets (``[engine](src/repro/core/engine.py)``) must exist
  on disk, resolved against the linking file's directory;
* anchor targets (``DESIGN.md#4-serving-architecture`` or in-file
  ``#quickstart``) must match a heading of the target file under GitHub's
  slug rules (lowercase, punctuation stripped, spaces -> hyphens);
* external links (``http(s)://``, ``mailto:``) are skipped — CI must not
  fail on third-party outages.

Usage: ``python tools/check_links.py [FILE ...]`` (defaults to the repo's
doc set); exits 1 and prints one line per broken link.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

DEFAULT_DOCS = ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
                "PAPER.md", "CHANGES.md")

# link text: anything but brackets; target: up to ')' or whitespace, with
# an optional "title" part after the target
_LINK = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop everything but word chars /
    spaces / hyphens, spaces to hyphens (`§5 Foo` -> `5-foo`)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)              # inline markup doesn't anchor
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _md_lines(path: Path):
    """Markdown lines outside fenced code blocks (a ``# comment`` in a bash
    fence is not a heading, and fenced text can't hold links)."""
    fenced = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            yield line


def _anchors(path: Path) -> set:
    out: set = set()
    counts: dict = {}
    for line in _md_lines(path):
        m = _HEADING.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        # GitHub suffixes repeated headings: slug, slug-1, slug-2, ...
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_file(path: Path) -> list:
    """All broken internal links of one markdown file."""
    errors = []
    text = "\n".join(_md_lines(path))
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        dest = (path.parent / file_part).resolve() if file_part else path
        if not dest.exists():
            errors.append(f"{path}: broken link target {target!r}")
            continue
        if anchor:
            if dest.suffix.lower() not in (".md", ".markdown"):
                continue   # anchors into non-markdown: not checkable
            if anchor not in _anchors(dest):
                errors.append(
                    f"{path}: broken anchor {target!r} "
                    f"(no heading slugs to {anchor!r} in {dest.name})")
    return errors


def main(argv: list) -> int:
    root = Path(__file__).resolve().parent.parent
    files = [Path(a) for a in argv] if argv else \
        [root / d for d in DEFAULT_DOCS if (root / d).exists()]
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        errors += check_file(f)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
