"""Per-architecture smoke tests on reduced same-family configs (CPU).

For each of the 10 assigned archs: forward shapes + finiteness, one
gradient/update step, and prefill+decode consistency against teacher
forcing (drop-free MoE capacity so routing is exact).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.smoke import reduced
from repro.models import forward, init_cache, init_params, loss_fn

ARCHS = list_archs()


def make_batch(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    shape = (B, cfg.codebooks, S) if cfg.codebooks else (B, S)
    tokens = jax.random.randint(ks[0], shape, 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    batch = {"tokens": tokens, "positions": pos, "labels": tokens}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            ks[1], (B, S, cfg.d_model), jnp.float32)
        batch["embed_mask"] = jnp.broadcast_to(
            jnp.arange(S)[None, :] < S // 4, (B, S))
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = reduced(get_config(request.param))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


def test_forward_shapes_and_finiteness(arch):
    cfg, params = arch
    B, S = 2, 16
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, _, aux = forward(params, cfg, batch, mode="train", remat="none")
    want = (B, cfg.codebooks, S, cfg.vocab_size) if cfg.codebooks \
        else (B, S, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_one_train_step_improves_loss(arch):
    cfg, params = arch
    batch = make_batch(cfg, 2, 16, jax.random.PRNGKey(2))

    def loss(p):
        return loss_fn(p, cfg, batch, remat="none")[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    p1 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, g)
    l1 = loss(p1)
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_prefill_decode_matches_teacher_forcing(arch):
    cfg, params = arch
    if cfg.num_experts:
        # drop-free capacity: routing identical between train and serve
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    B, S = 2, 12
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(3))
    batch.pop("labels")
    logits_all, _, _ = forward(params, cfg, batch, mode="train", remat="none")

    def sub(d, a, b):
        out = {"tokens": d["tokens"][..., a:b],
               "positions": d["positions"][..., a:b]}
        for k in ("frontend_embeds", "embed_mask"):
            if k in d:
                out[k] = d[k][:, a:b]
        return out

    cache = init_cache(cfg, B, max_len=S + 4)
    lp, cache, _ = forward(params, cfg, sub(batch, 0, S - 1), cache=cache,
                           mode="prefill")
    ld, cache, _ = forward(params, cfg, sub(batch, S - 1, S), cache=cache,
                           mode="decode")
    if cfg.codebooks:
        want, got = logits_all[:, :, S - 1], ld[:, :, 0]
        wantp, gotp = logits_all[:, :, S - 2], lp[:, :, -1]
    else:
        want, got = logits_all[:, S - 1], ld[:, 0]
        wantp, gotp = logits_all[:, S - 2], lp[:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gotp), np.asarray(wantp),
                               atol=2e-3, rtol=2e-3)


def test_multi_step_decode(arch):
    """Greedy-decode 4 tokens; logits stay finite and cache len advances."""
    cfg, params = arch
    B, S = 1, 8
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(4))
    cache = init_cache(cfg, B, max_len=S + 8)
    _, cache, _ = forward(
        params, cfg,
        {"tokens": batch["tokens"], "positions": batch["positions"]},
        cache=cache, mode="prefill")
    tok = batch["tokens"][..., -1:]
    for step in range(4):
        pos = jnp.full((B, 1), S + step, jnp.int32)
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[None], (3, B, 1))
        logits, cache, _ = forward(
            params, cfg, {"tokens": tok, "positions": pos},
            cache=cache, mode="decode")
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits[..., -1, :] if not cfg.codebooks
                         else logits[:, :, -1, :], axis=-1)
        tok = tok.reshape((B, cfg.codebooks, 1) if cfg.codebooks else (B, 1))


def test_param_count_analytics_close(arch):
    """Analytic param_count (used in roofline MODEL_FLOPS) within 20% of
    the true initialized count."""
    cfg, params = arch
    from repro.models import param_count
    true = param_count(params)
    est = cfg.param_count()
    assert 0.5 < est / true < 2.0, (est, true)
