"""Faithful reproduction of the paper's §5 simulation run.

Ground truth is the paper's own printed output for Π (Fig. 1) with
C0 = (2,1,1): the spiking vectors at C0, the successor sets it prints, the
``allGenCk`` list, and the semantic claim that Π generates ℕ∖{1}.
"""

import numpy as np
import pytest

from repro.core.engine import emission_gaps, explore, successor_set
from repro.core.matrix import compile_system
from repro.core.semantics import next_configs, spiking_vectors
from repro.core.system import paper_pi

import jax.numpy as jnp

# The paper's final allGenCk (§5).  NOTE: the paper's printed list contains
# '1-0-8' twice; as a set it has 47 unique entries.
PAPER_ALLGENCK = """
2-1-1 2-1-2 1-1-2 2-1-3 1-1-3 2-0-2 2-0-1 2-1-4 1-1-4 2-0-3 1-1-1
0-1-2 0-1-1 2-1-5 1-1-5 2-0-4 0-1-3 1-0-2 1-0-1 2-1-6 1-1-6 2-0-5 0-1-4
1-0-3 1-0-0 2-1-7 1-1-7 2-0-6 0-1-5 1-0-4 2-1-8 1-1-8 2-0-7 0-1-6 1-0-5
2-1-9 1-1-9 2-0-8 0-1-7 1-0-6 2-1-10 1-1-10 2-0-9 0-1-8 1-0-7 0-1-9
1-0-8 1-0-8 1-0-9
""".split()


@pytest.fixture(scope="module")
def comp_covering():
    return compile_system(paper_pi(covering=True))


@pytest.fixture(scope="module")
def comp_exact():
    return compile_system(paper_pi(covering=False))


def test_transition_matrix_matches_paper_eq1(comp_covering):
    expected = np.array(
        [[-1, 1, 1], [-2, 1, 1], [1, -1, 1], [0, 0, -1], [0, 0, -2]],
        dtype=np.int32,
    )
    np.testing.assert_array_equal(np.asarray(comp_covering.M), expected)
    # the paper's total order is preserved (rules already neuron-sorted)
    assert comp_covering.rule_order == (0, 1, 2, 3, 4)


def test_spiking_vectors_at_c0(comp_covering):
    """Paper §2.2: exactly <1,0,1,1,0> and <0,1,1,1,0> are valid at C0."""
    S, valid, overflow = spiking_vectors(
        jnp.array([2, 1, 1], jnp.int32), comp_covering, 8
    )
    assert not bool(overflow)
    got = {tuple(int(v) for v in S[i]) for i in np.nonzero(np.asarray(valid))[0]}
    assert got == {(1, 0, 1, 1, 0), (0, 1, 1, 1, 0)}


def test_successors_of_c0(comp_covering):
    succ = {c for c, _ in successor_set(comp_covering, (2, 1, 1))}
    assert succ == {(2, 1, 2), (1, 1, 2)}
    # both branches emit one spike to the environment (rule 4 fires)
    assert all(e == 1 for _, e in successor_set(comp_covering, (2, 1, 1)))


def test_successors_of_212_match_paper_trace(comp_covering):
    """The paper's run shows confVec 212 generating the *new* configs
    2-1-3 and 1-1-3 (plus revisits of 2-1-2 / 1-1-2)."""
    succ = {c for c, _ in successor_set(comp_covering, (2, 1, 2))}
    assert succ == {(2, 1, 3), (1, 1, 3), (2, 1, 2), (1, 1, 2)}


def test_allgenck_discovery_prefix(comp_covering):
    """BFS discovery order reproduces the paper's allGenCk.

    The first 45 entries match the paper's list *in order*; the paper's
    remaining tail {0-1-9, 1-0-8, 1-0-9} appears once its capped queue
    finished the non-spine branches (the 2-1-k spine is infinite — DESIGN.md
    §1.2), so we assert set-containment for the full list.
    """
    res = explore(comp_covering, max_steps=16, frontier_cap=128,
                  visited_cap=2048, max_branches=16)
    mine = res.as_strings()
    paper_unique = list(dict.fromkeys(PAPER_ALLGENCK))
    assert mine[:45] == paper_unique[:45]
    assert set(paper_unique) <= set(mine)


def test_zero_config_is_terminal(comp_covering):
    assert successor_set(comp_covering, (0, 0, 0)) == []
    # paper stopping criterion 1: a zero vector ends its branch
    res = explore(comp_covering, max_steps=4, frontier_cap=16,
                  visited_cap=64, max_branches=8, init=(0, 0, 0))
    assert res.num_discovered == 1  # only C0 itself


def test_dead_config_1_0_0_is_terminal(comp_covering):
    """(1,0,0) appears in the paper's tree; no rule is applicable there."""
    assert successor_set(comp_covering, (1, 0, 0)) == []


def test_exact_mode_generates_naturals_minus_one(comp_exact):
    """Under standard (exact) semantics Π generates ℕ∖{1}: the gap between
    the first two output spikes takes every value >= 2 and never 1."""
    gaps = emission_gaps(comp_exact, max_time=30, max_gap=14)
    assert 1 not in gaps
    assert set(range(2, 13)) <= gaps


def test_covering_mode_differs_from_exact(comp_covering):
    """The paper's implemented (b-3, >=) semantics admit gap 1 — evidence
    that its simulator semantics deviate from the original Π definition;
    recorded in DESIGN.md §1.2 and reproduced faithfully here."""
    gaps = emission_gaps(comp_covering, max_time=16, max_gap=8)
    assert 1 in gaps


def test_exact_mode_successors_of_212(comp_exact):
    succ = {c for c, _ in successor_set(comp_exact, (2, 1, 2))}
    assert succ == {(2, 1, 2), (1, 1, 2)}


def test_explore_reports_exhaustion_only_when_tree_finite(comp_covering):
    res = explore(comp_covering, max_steps=8, frontier_cap=128,
                  visited_cap=2048, max_branches=16)
    assert not res.exhausted  # Π's tree is infinite; 8 levels can't drain it
