"""Shape/dtype sweep for the fused SNP transition Pallas kernel vs. the
pure-jnp oracle (interpret mode; integer workload => exact equality)."""

import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compile_system, paper_pi
from repro.core.generators import nd_chain, random_system, ring, scaled_pi
from repro.kernels.snp_step import snp_step, snp_step_ref


def _assert_match(cfgs, comp, T, **blocks):
    o1, v1, e1, f1 = snp_step(cfgs, comp, max_branches=T, **blocks)
    o2, v2, e2, f2 = snp_step_ref(cfgs, comp, T)
    v1, v2 = np.asarray(v1), np.asarray(v2)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(
        np.where(v1[..., None], np.asarray(o1), 0),
        np.where(v2[..., None], np.asarray(o2), 0))
    np.testing.assert_array_equal(
        np.where(v1, np.asarray(e1), 0), np.where(v2, np.asarray(e2), 0))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


SYSTEMS = {
    "paper-pi": (paper_pi(True), 16),
    "paper-pi-exact": (paper_pi(False), 16),
    "ring-9": (ring(9), 8),
    "nd-chain-6": (nd_chain(6), 64),
    "random-17": (random_system(17, 3, 0.3, seed=3), 32),
    "random-33": (random_system(33, 2, 0.15, seed=7), 32),
    "pi-x5": (scaled_pi(5), 64),
}


@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_matches_oracle(name):
    system, T = SYSTEMS[name]
    comp = compile_system(system)
    rng = np.random.default_rng(hash(name) % 2**31)
    cfgs = jnp.asarray(
        rng.integers(0, 5, size=(6, comp.num_neurons)), jnp.int32)
    _assert_match(cfgs, comp, T, block_b=4, block_t=8, block_n=8)


@pytest.mark.parametrize("block_b,block_t,block_n", [
    (1, 4, 4), (2, 16, 16), (8, 32, 128), (4, 64, 8),
])
def test_block_shape_sweep(block_b, block_t, block_n):
    comp = compile_system(random_system(13, 3, 0.3, seed=11))
    rng = np.random.default_rng(0)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(7, 13)), jnp.int32)
    _assert_match(cfgs, comp, 32,
                  block_b=block_b, block_t=block_t, block_n=block_n)


def test_non_divisible_everything():
    """B, T, n, m all prime-ish: exercises every padding path."""
    comp = compile_system(random_system(11, 3, 0.4, seed=5))  # n = 33 rules
    rng = np.random.default_rng(2)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(5, 11)), jnp.int32)
    _assert_match(cfgs, comp, 17, block_b=4, block_t=16, block_n=16)


def test_branch_overflow_agreement():
    comp = compile_system(nd_chain(8))  # psi = 2^8 = 256 > T
    cfgs = jnp.ones((2, 8), jnp.int32)
    _assert_match(cfgs, comp, 32, block_b=2, block_t=16, block_n=16)


def test_large_spike_counts_exact():
    """f32 matmul must stay exact up to 2^24-scale spike counts."""
    comp = compile_system(paper_pi(True))
    cfgs = jnp.asarray([[2 ** 22, 1, 2 ** 20]], jnp.int32)
    _assert_match(cfgs, comp, 8, block_b=1, block_t=8, block_n=8)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_random_frontiers(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 12))
    system = random_system(m, int(rng.integers(1, 4)),
                           float(rng.uniform(0.1, 0.6)), seed=seed % 1000)
    comp = compile_system(system)
    cfgs = jnp.asarray(rng.integers(0, 5, size=(4, m)), jnp.int32)
    _assert_match(cfgs, comp, 32, block_b=2, block_t=8, block_n=8)
