"""SystemPlan layer tests: default-plan bit-identity for every registered
backend, hybrid ELL+COO encoding round-trips and ref-equivalence (the edge
cases a split in-adjacency can get wrong: zero tail, all tail, a single
hub, ruleless neurons), padding-memory wins on unbounded power-law graphs,
plan validation errors, and the sparse_pallas in-kernel COO stage (no
hybrid fallback — the kernel-lowering matrix itself is covered by
tests/test_kernel_lowering.py)."""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (SystemPlan, auto_hub_threshold, available_backends,
                        compile_system, compile_system_sparse, explore,
                        get_backend, paper_pi)
from repro.core.generators import power_law, random_system, ring_lattice
from repro.core.semantics import next_configs, sparse_next_configs
from repro.core.system import Rule, SNPSystem
from repro.kernels.snp_step import snp_step_sparse
from repro.sharding import neuron_axis

SYSTEMS = {
    "paper-pi": (paper_pi(True), 16),
    "random-17": (random_system(17, 3, 0.3, seed=3), 32),
    "ring-lattice-12": (ring_lattice(12, 3, seed=1), 16),
    "power-law-40": (power_law(40, 3, seed=3), 16),
}


def _assert_same_step(a, b):
    va, vb = np.asarray(a.valid), np.asarray(b.valid)
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(np.asarray(a.overflow),
                                  np.asarray(b.overflow))
    np.testing.assert_array_equal(
        np.where(va[..., None], np.asarray(a.configs), 0),
        np.where(vb[..., None], np.asarray(b.configs), 0))
    np.testing.assert_array_equal(
        np.where(va, np.asarray(a.emissions), 0),
        np.where(vb, np.asarray(b.emissions), 0))


def _in_degrees(system):
    syn = np.asarray(system.synapses).reshape(-1, 2)
    return np.bincount(syn[:, 1], minlength=system.num_neurons)


# ---------------------------------------------------------------------------
# the plan object
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="encoding"):
        SystemPlan(encoding="csr")
    with pytest.raises(ValueError, match="hub_threshold"):
        SystemPlan(encoding="hybrid", hub_threshold=0)
    with pytest.raises(ValueError, match="num_shards"):
        SystemPlan(num_shards=0)
    # hashable (rides through jit static args with the backend)
    assert hash(SystemPlan()) == hash(SystemPlan.default())


def test_for_system_decision_rules():
    """Hybrid iff the max in-degree is heavy-tailed vs the auto threshold
    (module docstring of core.plan): regular lattices stay ELL, unbounded
    power-law hubs flip to hybrid once the hub outgrows 2x the
    threshold."""
    lattice = ring_lattice(64, 4, seed=0)
    assert SystemPlan.for_system(lattice).encoding == "ell"
    hubby = power_law(400, 3, seed=0)           # max_in=None: unbounded hub
    in_deg = _in_degrees(hubby)
    h = auto_hub_threshold(in_deg)
    assert int(in_deg.max()) > 2 * h            # the family is heavy-tailed
    plan = SystemPlan.for_system(hubby)
    assert plan.encoding == "hybrid" and plan.hub_threshold == h


def test_neuron_axis_helper():
    plan = neuron_axis(8)
    assert plan.num_shards == 8 and plan.encoding == "ell"
    plan = neuron_axis(4, encoding="hybrid", hub_threshold=6)
    assert (plan.num_shards, plan.encoding, plan.hub_threshold) == \
        (4, "hybrid", 6)


# ---------------------------------------------------------------------------
# default plan == pre-refactor output, for every registered backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(available_backends()))
def test_default_plan_is_bit_identical(name):
    """Registry-driven: compile with no plan, the default plan, and
    plan=None must produce identical encodings (leaf-for-leaf) and
    identical expand outputs for every backend."""
    system, T = SYSTEMS["random-17"]
    be = get_backend(name)
    plain = be.compile(system)
    planned = be.compile(system, plan=SystemPlan.default())
    assert jax.tree.structure(plain) == jax.tree.structure(planned)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(planned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rng = np.random.default_rng(0)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(5, 17)), jnp.int32)
    _assert_same_step(be.expand(cfgs, plain, T),
                      be.expand(cfgs, planned, T))


@pytest.mark.parametrize("name", sorted(available_backends()))
def test_backends_reject_foreign_plan_encodings(name):
    system = paper_pi(True)
    be = get_backend(name)
    dense = be.name in ("ref", "pallas")
    bad = "hybrid" if dense else "dense"
    assert bad not in be.supported_encodings()
    with pytest.raises(ValueError, match="cannot realize"):
        be.compile(system, plan=SystemPlan(encoding=bad))


@pytest.mark.parametrize("name", sorted(available_backends()))
def test_every_backend_lowers_sharded_plans(name):
    """The lowering registry declares 'sharded' for every built-in
    backend, and compile(num_shards > 1) lowers to a ShardedCompiled for
    all of them (consumed by explore_distributed)."""
    from repro.core import is_sharded

    be = get_backend(name)
    assert "sharded" in be.supported_encodings()
    sc = be.compile(paper_pi(True), plan=SystemPlan(num_shards=2))
    assert is_sharded(sc) and sc.num_shards == 2
    if name == "pallas":  # dense kernel operands attached by lower()
        assert sc.dense is not None
        assert sc.dense.M_local.shape[0] == 2
    else:
        assert sc.dense is None


def test_single_device_consumers_reject_sharded_plans():
    from repro.core import run_traces
    from repro.core.distributed import run_traces_distributed

    with pytest.raises(ValueError, match="explore_distributed"):
        explore(paper_pi(True), plan=SystemPlan(num_shards=2))
    with pytest.raises(ValueError, match="explore_distributed"):
        run_traces(paper_pi(True), steps=4, seeds=[0],
                   plan=SystemPlan(num_shards=2))
    with pytest.raises(ValueError, match="explore_distributed"):
        run_traces_distributed(paper_pi(True), steps=4, seeds=[0],
                               plan=SystemPlan(num_shards=2))


# ---------------------------------------------------------------------------
# hybrid ELL+COO: encoding round-trips + ref equivalence
# ---------------------------------------------------------------------------

def _in_adjacency_sets(sp):
    """{target: sorted in-neighbors} reassembled from ELL part + COO tail."""
    m = sp.num_neurons
    out = {j: [] for j in range(m)}
    ii = np.asarray(sp.in_idx)
    for j in range(m):
        out[j] += [int(x) for x in ii[j] if x < m]
    for s, d in zip(np.asarray(sp.coo_src), np.asarray(sp.coo_dst)):
        out[int(d)].append(int(s))
    return {j: sorted(v) for j, v in out.items()}


@pytest.mark.parametrize("name", sorted(SYSTEMS))
@pytest.mark.parametrize("threshold", [1, 2, 1000])
def test_hybrid_round_trips_and_matches_ref(name, threshold):
    """ELL part + COO tail must reassemble exactly the synapse graph's
    in-adjacency at any split point, and the step must stay bit-identical
    to the dense oracle.  threshold=1 is the all-tail extreme, 1000 the
    zero-tail extreme (== pure ELL)."""
    system, T = SYSTEMS[name]
    dn = compile_system(system)
    hy = compile_system_sparse(system, hub_threshold=threshold)
    got = _in_adjacency_sets(hy)
    for j in range(system.num_neurons):
        assert got[j] == sorted(i for (i, jj) in system.synapses if jj == j)
    # split accounting: the ELL width is capped, tail picks up the rest
    in_deg = _in_degrees(system)
    assert hy.max_in_degree == min(max(1, int(in_deg.max())), threshold)
    assert hy.coo_src.shape[0] == int(
        np.maximum(in_deg - threshold, 0).sum())
    assert hy.is_hybrid == (hy.coo_src.shape[0] > 0)
    if threshold == 1000:  # zero tail: arrays equal the pure-ELL lowering
        pure = compile_system_sparse(system)
        np.testing.assert_array_equal(np.asarray(hy.in_idx),
                                      np.asarray(pure.in_idx))
        assert hy.coo_src.shape == (0,)
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    cfgs = jnp.asarray(rng.integers(0, 5, size=(6, dn.num_neurons)),
                       jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, T),
                      sparse_next_configs(cfgs, hy, T))


def test_hybrid_single_hub_and_ruleless_neurons():
    """One hub with every in-synapse in the tail, fed by ruleless
    neurons: the segment-sum must still land every contribution."""
    m = 6
    rules = (
        Rule(neuron=0, consume=1, produce=2, regex_base=1, covering=True),
        Rule(neuron=1, consume=1, produce=1, regex_base=1, covering=True),
        Rule(neuron=2, consume=1, produce=1, regex_base=1, covering=True),
        # neurons 3, 4 own no rules; 5 is the hub with no rules either
    )
    syn = tuple((i, 5) for i in range(5)) + ((0, 1), (1, 2))
    system = SNPSystem(m, (1, 1, 1, 0, 0, 0), rules, syn, output_neuron=2)
    dn = compile_system(system)
    hy = compile_system_sparse(system, hub_threshold=1)
    assert hy.is_hybrid and int(np.asarray(hy.coo_dst).max()) == 5
    cfgs = jnp.asarray([[1, 1, 1, 0, 0, 0], [2, 0, 1, 1, 1, 5],
                        [0, 0, 0, 0, 0, 0]], jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, 8),
                      sparse_next_configs(cfgs, hy, 8))


def test_hybrid_strictly_less_padding_on_unbounded_power_law():
    """Acceptance criterion: on a power-law graph without ``max_in`` the
    hybrid encoding must spend strictly fewer in-adjacency slots (ELL
    padding included) than pure ELL, while matching ref exactly."""
    system = power_law(400, 3, seed=2)          # unbounded hubs
    plan = SystemPlan.for_system(system)
    assert plan.encoding == "hybrid"
    be = get_backend("sparse")
    pure = compile_system_sparse(system)
    hy = be.compile(system, plan=plan)
    assert hy.is_hybrid
    assert hy.in_adjacency_slots < pure.in_adjacency_slots
    dn = compile_system(system)
    rng = np.random.default_rng(7)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(4, 400)), jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, 8),
                      be.expand(cfgs, hy, 8))


def test_explore_with_hybrid_plan_matches_ref():
    system = power_law(24, 3, seed=4)
    kw = dict(max_steps=4, frontier_cap=128, visited_cap=1024,
              max_branches=32)
    ref = explore(system, backend="ref", **kw)
    got = explore(system, backend="sparse",
                  plan=SystemPlan(encoding="hybrid", hub_threshold=2), **kw)
    np.testing.assert_array_equal(ref.configs, got.configs)
    assert ref.exhausted == got.exhausted


# ---------------------------------------------------------------------------
# sparse_pallas: the hybrid encoding runs in-kernel (COO segment-sum
# stage) — no fallback warning, no shape crash, and a metadata-less
# hand-built encoding raises instead of silently downgrading
# ---------------------------------------------------------------------------

def test_sparse_pallas_runs_hybrid_in_kernel_without_fallback():
    system, T = SYSTEMS["power-law-40"]
    be = get_backend("sparse_pallas")
    hy = be.compile(system, plan=SystemPlan(encoding="hybrid",
                                            hub_threshold=2))
    assert hy.is_hybrid and hy.coo_bounds is not None
    rng = np.random.default_rng(1)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(3, 40)), jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any fallback warning fails
        got = be.expand(cfgs, hy, T)
    ref = get_backend("ref")
    _assert_same_step(ref.expand(cfgs, ref.compile(system), T), got)


def test_sparse_pallas_rejects_hybrid_without_coo_metadata():
    """A hybrid encoding that cannot lower (hand-built, no segment
    metadata) must raise — never warn-and-downgrade (PR-4 contract)."""
    system, T = SYSTEMS["power-law-40"]
    hy = compile_system_sparse(system, hub_threshold=2)
    cfgs = jnp.zeros((2, system.num_neurons), jnp.int32)
    be = get_backend("sparse_pallas")
    for stripped in (hy._replace(coo_bounds=None, hub_slot=None),
                     hy._replace(hub_slot=None),
                     hy._replace(coo_bounds=None)):
        with pytest.raises(ValueError, match="coo_bounds"):
            snp_step_sparse(cfgs, stripped, max_branches=T)
        with pytest.raises(ValueError, match="cannot lower"):
            be.expand(cfgs, stripped, T)


def test_sparse_pallas_ops_serve_hybrid_bit_identically():
    """The raw op now carries the COO stage: hybrid == jnp sparse oracle."""
    system, T = SYSTEMS["power-law-40"]
    hy = compile_system_sparse(system, hub_threshold=2)
    rng = np.random.default_rng(5)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(4, 40)), jnp.int32)
    out, valid, emis, ovf = snp_step_sparse(cfgs, hy, max_branches=T,
                                            block_b=2, block_t=8)
    ref = sparse_next_configs(cfgs, hy, T)

    from types import SimpleNamespace
    _assert_same_step(ref, SimpleNamespace(configs=out, valid=valid,
                                           emissions=emis, overflow=ovf))


def test_sparse_pallas_pure_ell_still_uses_the_kernel():
    """No warnings on pure-ELL encodings either."""
    system, T = SYSTEMS["ring-lattice-12"]
    be = get_backend("sparse_pallas")
    comp = be.compile(system)
    cfgs = jnp.zeros((2, 12), jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        be.expand(cfgs, comp, T)
