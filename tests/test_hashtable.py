"""Device-resident hash-table dedup (``core.hashtable``).

Three layers:

* direct primitives — batched insert-if-absent verdicts, idempotence,
  probe wraparound at high load factor, the sentinel-key remap, and the
  bounded-probe overflow flag at capacity;
* hypothesis differential — insert-if-absent over random key batches
  (with forced duplicates and the sentinel key) against a Python dict
  oracle replaying the same first-occurrence rule;
* engine equivalence — ``explore(dedup="hash")`` must reproduce the
  sort-based archive **bit-for-bit** (row order included: the
  first-occurrence claim reproduces the stable sort's lowest-index
  winner) across the backend x encoding x semantics registry matrix.
"""

import numpy as np
import pytest

import conftest
from repro.core import SystemPlan, explore, paper_pi
from repro.core.generators import power_law, random_system
from repro.core.hashing import SENTINEL
from repro.core.hashtable import (insert_if_absent, lookup, make_table,
                                  table_slots)


def _keys(rng, n):
    return (rng.integers(0, 2**32, size=n, dtype=np.uint64).astype(np.uint32),
            rng.integers(0, 2**32, size=n, dtype=np.uint64).astype(np.uint32))


# ---------------------------------------------------------------------------
# direct primitives
# ---------------------------------------------------------------------------


def test_insert_if_absent_first_occurrence_and_idempotence():
    hi = np.array([1, 2, 1, 3, 2, 1], np.uint32)
    lo = np.array([9, 9, 9, 9, 9, 9], np.uint32)
    valid = np.ones(6, bool)
    table = make_table(16)
    table, new, ovf = insert_if_absent(table, hi, lo, valid)
    # lowest-index occurrence of each distinct key wins, duplicates lose
    np.testing.assert_array_equal(np.asarray(new),
                                  [True, True, False, True, False, False])
    assert not bool(ovf)
    assert int(table.count) == 3
    # re-inserting the same batch is a no-op
    table, new2, ovf = insert_if_absent(table, hi, lo, valid)
    assert not np.asarray(new2).any() and not bool(ovf)
    assert int(table.count) == 3
    found, _ = lookup(table, hi, lo, valid)
    assert np.asarray(found).all()


def test_invalid_lanes_never_insert():
    hi = np.array([5, 6, 7], np.uint32)
    lo = np.array([5, 6, 7], np.uint32)
    valid = np.array([True, False, True])
    table, new, _ = insert_if_absent(make_table(8), hi, lo, valid)
    np.testing.assert_array_equal(np.asarray(new), [True, False, True])
    assert int(table.count) == 2
    found, _ = lookup(table, hi, lo, np.ones(3, bool))
    np.testing.assert_array_equal(np.asarray(found), [True, False, True])


def test_sentinel_key_is_a_real_storable_key():
    """(SENTINEL, SENTINEL) is remapped away from the empty-slot marker,
    so a config whose hash happens to be all-ones still dedups."""
    hi = np.array([SENTINEL, SENTINEL], np.uint32)
    lo = np.array([SENTINEL, SENTINEL], np.uint32)
    table, new, _ = insert_if_absent(make_table(8), hi, lo,
                                     np.ones(2, bool))
    np.testing.assert_array_equal(np.asarray(new), [True, False])
    table, new2, _ = insert_if_absent(table, hi, lo, np.ones(2, bool))
    assert not np.asarray(new2).any()
    # the remap deliberately aliases (S, S) onto (S, S-1) — one extra
    # 2^-64-grade collision pair, not a correctness hole: the alias
    # dedups consistently rather than colliding with the empty marker
    lo2 = np.array([SENTINEL - 1], np.uint32)
    _, new3, _ = insert_if_absent(table, hi[:1], lo2, np.ones(1, bool))
    assert not np.asarray(new3).any()


def test_probe_wraparound_at_high_load():
    """Fill a tiny table close to its slot count: probes must wrap past
    the end of the array and still find empty slots / prior keys."""
    rng = np.random.default_rng(7)
    n = 12   # table_slots(12) == 32 slots, load 0.375 after one batch
    hi, lo = _keys(rng, n)
    table = make_table(n)
    table, new, ovf = insert_if_absent(table, hi, lo, np.ones(n, bool))
    assert np.asarray(new).all() and not bool(ovf)
    # a second distinct batch drives load towards 0.75 — still no flag
    hi2, lo2 = _keys(rng, n)
    table, new2, ovf = insert_if_absent(table, hi2, lo2, np.ones(n, bool))
    assert np.asarray(new2).all() and not bool(ovf)
    for h, l in ((hi, lo), (hi2, lo2)):
        found, _ = lookup(table, h, l, np.ones(n, bool))
        assert np.asarray(found).all()


def test_overflow_flag_at_capacity():
    """Driving the table past its slot count must raise the overflow
    flag (bounded probes give up) instead of looping or silently
    corrupting earlier entries."""
    rng = np.random.default_rng(3)
    table = make_table(4)          # 16 slots
    S = table.num_slots
    hi, lo = _keys(rng, 4 * S)
    table, _, ovf = insert_if_absent(table, hi, lo, np.ones(4 * S, bool))
    assert bool(ovf)
    assert int(table.count) <= S
    # keys reported found must really be present (flag, not corruption)
    found, _ = lookup(table, hi[:8], lo[:8], np.ones(8, bool))
    refound, _ = lookup(table, hi[:8], lo[:8], np.ones(8, bool))
    np.testing.assert_array_equal(np.asarray(found), np.asarray(refound))


def test_payloads_roundtrip():
    rng = np.random.default_rng(11)
    hi, lo = _keys(rng, 20)
    pay = np.arange(100, 120, dtype=np.int32)
    table, new, _ = insert_if_absent(make_table(32), hi, lo,
                                     np.ones(20, bool), payload=pay)
    assert np.asarray(new).all()
    found, got = lookup(table, hi, lo, np.ones(20, bool))
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(got), pay)


def test_table_slots_sizing():
    assert table_slots(4) == 16
    for cap in (5, 100, 2048, 4097):
        s = table_slots(cap)
        assert s >= 2 * cap and (s & (s - 1)) == 0
    with pytest.raises(ValueError, match="capacity"):
        table_slots(0)


def test_insert_if_absent_is_one_jittable_call():
    """The whole batched insert-if-absent traces as one jitted program
    (the table is a pytree carry)."""
    import jax
    rng = np.random.default_rng(5)
    hi, lo = _keys(rng, 16)
    fn = jax.jit(insert_if_absent)
    t2, new, ovf = fn(make_table(64), hi, lo, np.ones(16, bool))
    assert np.asarray(new).all() and not bool(ovf)
    assert int(t2.count) == 16


# ---------------------------------------------------------------------------
# hypothesis differential against a dict oracle
# ---------------------------------------------------------------------------


def test_insert_if_absent_matches_dict_oracle_property():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    key = st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
    # small key universe forces duplicates within and across batches;
    # always include the sentinel key once in the pool
    pool = st.lists(key, min_size=1, max_size=8).map(
        lambda ks: ks + [(int(SENTINEL), int(SENTINEL))])

    @settings(max_examples=40, deadline=None)
    @given(pool=pool, data=st.data())
    def run(pool, data):
        batches = data.draw(st.lists(
            st.lists(st.sampled_from(pool), min_size=1, max_size=12),
            min_size=1, max_size=4))
        table = make_table(64)
        seen = {}
        for batch in batches:
            hi = np.array([k[0] for k in batch], np.uint32)
            lo = np.array([k[1] for k in batch], np.uint32)
            table, new, ovf = insert_if_absent(table, hi, lo,
                                               np.ones(len(batch), bool))
            assert not bool(ovf)
            want = []
            batch_seen = set()
            for k in batch:
                fresh = k not in seen and k not in batch_seen
                want.append(fresh)
                if fresh:
                    seen[k] = True
                batch_seen.add(k)
            np.testing.assert_array_equal(np.asarray(new), want)
            assert int(table.count) == len(seen)

    run()


# ---------------------------------------------------------------------------
# engine equivalence: hash dedup == sort dedup, bit for bit
# ---------------------------------------------------------------------------


def _explore_both(system, *, plan=None, backend=None, **kw):
    a = explore(system, plan=plan, backend=backend, dedup="sort", **kw)
    b = explore(system, plan=plan, backend=backend, dedup="hash", **kw)
    return a, b


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.configs),
                                  np.asarray(b.configs))
    assert a.num_discovered == b.num_discovered
    assert a.steps == b.steps
    assert a.exhausted == b.exhausted
    assert (a.branch_overflow, a.frontier_overflow) == \
        (b.branch_overflow, b.frontier_overflow)


def test_hash_explore_bit_identical_registry_matrix(lowering_cell):
    """Row-for-row archive identity across every (backend, encoding,
    semantics) registry cell: the scatter-min first-occurrence claim
    must reproduce the stable sort's lowest-index winner everywhere."""
    name, plan = lowering_cell
    system = random_system(9, 2, 0.3, seed=1)
    if plan.semantics == "delays":
        system = conftest.delayed_variant(system)
    a, b = _explore_both(system, plan=plan, backend=name, max_steps=6,
                         frontier_cap=64, visited_cap=1024, max_branches=32)
    _assert_same_result(a, b)
    assert a.visited_overflow == b.visited_overflow


def test_hash_explore_bit_identical_under_overflow():
    """Branch + frontier overflow regime: truncation verdicts (which
    candidates survive into the frontier) must also agree, or the two
    paths would explore different subtrees."""
    a, b = _explore_both(power_law(40, 3, seed=3), max_steps=8,
                         frontier_cap=32, visited_cap=4096, max_branches=8)
    assert a.branch_overflow and a.frontier_overflow
    _assert_same_result(a, b)


def test_hash_explore_exhausts_finite_system():
    from repro.core.generators import counter
    a, b = _explore_both(counter(5), max_steps=48, frontier_cap=64,
                         visited_cap=512, max_branches=16)
    assert b.exhausted
    _assert_same_result(a, b)


def test_hash_explore_paper_pi():
    a, b = _explore_both(paper_pi(True), max_steps=32, frontier_cap=64,
                         visited_cap=512, max_branches=16)
    _assert_same_result(a, b)


def test_hash_explore_visited_overflow_is_flagged_and_sound():
    """Past the visited capacity the two drop policies legitimately
    differ; both must flag, and the hash archive must stay a subset of
    the truth."""
    system = power_law(40, 3, seed=3)
    big = explore(system, max_steps=8, frontier_cap=32,
                  visited_cap=65536, max_branches=8, dedup="sort")
    truth = {tuple(r) for r in np.asarray(big.configs)}
    for dedup in ("sort", "hash"):
        r = explore(system, max_steps=8, frontier_cap=32, visited_cap=64,
                    max_branches=8, dedup=dedup)
        assert r.visited_overflow
        assert {tuple(r) for r in np.asarray(r.configs)} <= truth


def test_explore_rejects_unknown_dedup():
    with pytest.raises(ValueError, match="dedup"):
        explore(paper_pi(True), dedup="bloom")


def test_dedup_auto_resolution():
    """The default picks the table only when the visited capacity clears
    the absolute floor AND dominates the wave; explicit modes pass
    through untouched (both produce identical archives, so the rule only
    moves wall-time)."""
    from repro.core.engine import resolve_dedup

    # counter/power-law shape: tiny wave, big visited capacity -> table
    assert resolve_dedup("auto", frontier_cap=16, visited_cap=16384,
                         max_branches=8) == "hash"
    # paper-pi tree-bench shape: wave as big as the capacity -> sort
    assert resolve_dedup("auto", frontier_cap=128, visited_cap=2048,
                         max_branches=16) == "sort"
    # big capacity but wave-dominated (pi_x4 shape) -> sort
    assert resolve_dedup("auto", frontier_cap=512, visited_cap=16384,
                         max_branches=64) == "sort"
    # small capacity never takes the table, however tiny the wave
    assert resolve_dedup("auto", frontier_cap=1, visited_cap=8192,
                         max_branches=1) == "sort"
    for explicit in ("hash", "sort"):
        assert resolve_dedup(explicit, frontier_cap=1, visited_cap=1,
                             max_branches=1) == explicit
    with pytest.raises(ValueError, match="dedup"):
        resolve_dedup("bloom", frontier_cap=1, visited_cap=1, max_branches=1)


def test_dedup_auto_bit_identical_to_both():
    sys_ = random_system(9, 2, 0.3, seed=1)
    kw = dict(max_steps=6, frontier_cap=64, visited_cap=1024, max_branches=16)
    auto = explore(sys_, dedup="auto", **kw)
    for explicit in ("sort", "hash"):
        ref = explore(sys_, dedup=explicit, **kw)
        assert auto.num_discovered == ref.num_discovered
        np.testing.assert_array_equal(
            auto.configs[:auto.num_discovered],
            ref.configs[:ref.num_discovered])
