"""Synthetic system families: structural invariants and, for ``counter``,
the exact trajectory the docstring promises (period-2^b limit cycle)."""

import numpy as np
import pytest

from repro.core import compile_system, explore, run_trace
from repro.core.generators import counter, nd_chain, ring, scaled_pi


def test_ring_cycles_one_spike():
    comp = compile_system(ring(5))
    cfgs, _, alive, *_ = run_trace(comp, steps=10, policy="first")
    cfgs = np.asarray(cfgs)
    assert np.asarray(alive).all()
    assert (cfgs.sum(axis=1) == 1).all()          # exactly one spike in flight
    np.testing.assert_array_equal(cfgs[4], cfgs[9])  # period m


def test_nd_chain_branching_width():
    comp = compile_system(nd_chain(4))
    # Psi = 2^4 = 16 at C0: capping branches below that must flag overflow,
    # a sufficient cap must not (and then the small tree drains completely).
    capped = explore(comp, max_steps=6, frontier_cap=256, visited_cap=2048,
                     max_branches=8)
    assert capped.branch_overflow
    res = explore(comp, max_steps=6, frontier_cap=256, visited_cap=2048,
                  max_branches=32)
    assert not res.branch_overflow
    assert res.exhausted
    assert res.num_discovered > 1


@pytest.mark.parametrize("bits", [1, 3, 4])
def test_counter_is_period_doubling(bits):
    """The b-bit ripple counter must visit >= 2^b distinct configurations,
    settle into a period-2^b limit cycle, and emit to the environment
    exactly every 2^b steps."""
    sysm = counter(bits)
    assert sysm.num_neurons == bits + 2   # 2-neuron pacemaker + b dividers
    comp = compile_system(sysm)
    P = 2 ** bits
    steps = 3 * P + 2 * bits + 8
    cfgs, emis, alive, *_ = run_trace(comp, steps=steps, policy="first")
    cfgs, emis = np.asarray(cfgs), np.asarray(emis)
    assert np.asarray(alive).all()        # deterministic, never dies

    distinct = {tuple(row) for row in cfgs}
    assert len(distinct) >= P             # the docstring's 2^b configs

    # eventually periodic with period exactly 2^b
    half = len(cfgs) // 2
    np.testing.assert_array_equal(cfgs[half:-P], cfgs[half + P:])
    if P > 1:                             # ... and no shorter period
        assert not np.array_equal(cfgs[half], cfgs[half + P // 2])

    # output spike train: one emission every 2^b steps
    times = np.nonzero(emis)[0]
    assert len(times) >= 2
    assert set(np.diff(times).tolist()) == {P}


def test_counter_rejects_zero_bits():
    with pytest.raises(ValueError, match="bits"):
        counter(0)


def test_scaled_pi_is_disjoint_product():
    base = compile_system(scaled_pi(1))
    doubled = compile_system(scaled_pi(2))
    assert doubled.num_neurons == 2 * base.num_neurons
    assert doubled.num_rules == 2 * base.num_rules
    r1 = explore(base, max_steps=3, frontier_cap=64, visited_cap=512,
                 max_branches=16)
    r2 = explore(doubled, max_steps=3, frontier_cap=256, visited_cap=2048,
                 max_branches=64)
    # copies step in lockstep, so every reachable product config projects to
    # a reachable config of the factor on both halves (the converse needs
    # the factors reachable at the *same* depth, so |r2| <= |r1|^2)
    m0 = base.num_neurons
    factor = {tuple(r) for r in r1.configs}
    assert r1.num_discovered < r2.num_discovered <= r1.num_discovered ** 2
    for row in r2.configs:
        assert tuple(row[:m0]) in factor and tuple(row[m0:]) in factor


def test_sparse_topology_generators_are_bounded_degree():
    from repro.core.generators import power_law, ring_lattice, torus

    rl = ring_lattice(64, degree=5, seed=1)
    assert all(rl.out_degree(i) == 5 for i in range(64))
    tor = torus(4, 6, seed=1)
    assert tor.num_neurons == 24
    assert all(tor.out_degree(i) == 4 for i in range(24))
    pl_ = power_law(80, attach=3, seed=1, max_in=12)
    in_deg = [0] * 80
    for _, j in pl_.synapses:
        in_deg[j] += 1
    assert max(in_deg) <= 12
    assert all(pl_.out_degree(i) == 3 for i in range(4, 80))


def test_power_law_terminates_under_tight_in_degree_cap():
    """max_in close to attach used to spin forever in rejection sampling;
    it must now either generate (cap honored) or fail fast."""
    from repro.core.generators import power_law

    with pytest.raises(ValueError, match="max_in"):
        power_law(12, attach=4, max_in=4)
    with pytest.raises(ValueError, match="attach"):
        power_law(10, attach=3, max_in=2)   # guard: max_in < attach
