"""Async trace-service tests: futures drain, flush triggers, error
propagation, service edge cases, and the pluggable runner (mesh path).

The async-mode contract under test (DESIGN.md §4): results are
bit-identical to a synchronous ``drain()`` of the same requests — batching,
padding and flush timing must never change a trajectory — and every failure
mode surfaces through the submit futures, never a crashed drain thread.
"""

import threading
import time

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import compile_system, paper_pi, run_trace
from repro.core.generators import nd_chain, random_system
from repro.serve import (SNPTraceService, TraceRequest, make_trace_runner)

PI = paper_pi(True)
TIMEOUT = 120  # generous future timeouts: CI boxes compile slowly


def _mixed_requests():
    chain = nd_chain(4)
    return [
        TraceRequest(PI, steps=5, policy="random", seed=7),
        TraceRequest(PI, steps=11, policy="random", seed=9),   # same group
        TraceRequest(PI, steps=6, policy="first"),
        TraceRequest(chain, steps=4, policy="random", seed=1, max_branches=32),
    ]


def _assert_result_equal(a, b):
    np.testing.assert_array_equal(a.configs, b.configs)
    np.testing.assert_array_equal(a.emissions, b.emissions)
    np.testing.assert_array_equal(a.alive, b.alive)


# ---------------------------------------------------------------------------
# async == sync
# ---------------------------------------------------------------------------

def test_async_results_bit_identical_to_sync_drain():
    reqs = _mixed_requests()
    sync = SNPTraceService(batch_size=8, step_bucket=8)
    tickets = [sync.submit(r) for r in reqs]
    expected = sync.drain()
    with SNPTraceService(batch_size=8, step_bucket=8, async_mode=True,
                         max_delay_ms=20) as svc:
        futs = [svc.submit(r) for r in reqs]
        for t, fut in zip(tickets, futs):
            _assert_result_equal(expected[t], fut.result(timeout=TIMEOUT))


def test_async_submit_returns_future_and_drain_is_rejected():
    with SNPTraceService(async_mode=True, max_delay_ms=1) as svc:
        fut = svc.submit(TraceRequest(PI, steps=3))
        assert hasattr(fut, "result")  # concurrent.futures.Future
        with pytest.raises(RuntimeError, match="sync-mode only"):
            svc.drain()
        fut.result(timeout=TIMEOUT)


# ---------------------------------------------------------------------------
# flush triggers
# ---------------------------------------------------------------------------

def test_full_group_flushes_without_deadline_or_close():
    # deadline far away: only the group-full trigger can flush these
    svc = SNPTraceService(batch_size=4, step_bucket=4, async_mode=True,
                          max_delay_ms=60_000)
    try:
        futs = [svc.submit(TraceRequest(PI, steps=3, policy="random", seed=s))
                for s in range(4)]
        for s, fut in enumerate(futs):
            got = fut.result(timeout=TIMEOUT)
            c, _, _, *_ = run_trace(PI, steps=3, policy="random", seed=s)
            np.testing.assert_array_equal(got.configs, np.asarray(c))
        assert svc.num_device_calls == 1
    finally:
        svc.close()


def test_partial_group_flushes_at_deadline():
    svc = SNPTraceService(batch_size=64, step_bucket=4, async_mode=True,
                          max_delay_ms=10)
    try:
        fut = svc.submit(TraceRequest(PI, steps=3, policy="random", seed=5))
        got = fut.result(timeout=TIMEOUT)   # << batch_size: deadline fires
        c, e, _, *_ = run_trace(PI, steps=3, policy="random", seed=5)
        np.testing.assert_array_equal(got.configs, np.asarray(c))
        np.testing.assert_array_equal(got.emissions, np.asarray(e))
    finally:
        svc.close()


def test_close_flushes_pending_and_is_idempotent():
    svc = SNPTraceService(batch_size=64, step_bucket=4, async_mode=True,
                          max_delay_ms=60_000)
    futs = [svc.submit(TraceRequest(PI, steps=3, policy="random", seed=s))
            for s in range(3)]
    svc.close()
    assert all(f.done() for f in futs)
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(TraceRequest(PI, steps=3))


def test_cancelled_future_does_not_kill_the_drain_thread():
    """fut.cancel() must be skipped at flush time, not written to (writing
    a cancelled Future raises and would kill the drain thread, hanging
    every sibling and later submission)."""
    svc = SNPTraceService(batch_size=4, step_bucket=4, async_mode=True,
                          max_delay_ms=60_000)
    try:
        futs = [svc.submit(TraceRequest(PI, steps=3, policy="random", seed=s))
                for s in range(3)]
        assert futs[1].cancel()
        futs.append(svc.submit(      # fills the group -> flush fires
            TraceRequest(PI, steps=3, policy="random", seed=3)))
        for s in (0, 2, 3):
            got = futs[s].result(timeout=TIMEOUT)   # siblings unharmed
            c, _, _, *_ = run_trace(PI, steps=3, policy="random", seed=s)
            np.testing.assert_array_equal(got.configs, np.asarray(c))
        assert futs[1].cancelled()
        # the thread survived: a later submission still serves
        late = svc.submit(TraceRequest(PI, steps=3, seed=9))
        svc.close()
        assert late.result(timeout=TIMEOUT) is not None
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# error propagation
# ---------------------------------------------------------------------------

def test_flush_error_propagates_into_futures_and_thread_survives():
    calls = {"n": 0}

    def flaky(comp, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("kaboom")
        from repro.core.engine import run_traces
        return run_traces(comp, **kw)

    with SNPTraceService(batch_size=2, async_mode=True, max_delay_ms=1,
                         runner=flaky) as svc:
        bad = svc.submit(TraceRequest(PI, steps=3, seed=1))
        err = bad.exception(timeout=TIMEOUT)
        assert isinstance(err, RuntimeError) and "kaboom" in str(err)
        # the drain thread must survive a failed flush and serve the next
        good = svc.submit(TraceRequest(PI, steps=3, seed=1))
        got = good.result(timeout=TIMEOUT)
        c, _, _, *_ = run_trace(PI, steps=3, seed=1)
        np.testing.assert_array_equal(got.configs, np.asarray(c))


# ---------------------------------------------------------------------------
# service edge cases (sync mode)
# ---------------------------------------------------------------------------

def test_drain_with_zero_pending_returns_empty():
    svc = SNPTraceService(batch_size=4)
    assert svc.drain() == {}
    assert svc.num_device_calls == 0


@pytest.mark.parametrize("failing_call", [1, 2])
def test_failed_sync_drain_keeps_all_requests_for_retry(failing_call):
    """A runner error in ANY chunk of a drain must not lose requests: the
    whole drain stays pending (all-or-nothing) and a retry serves it all —
    including chunks that already succeeded before the failing one (their
    re-run is deterministic, so nothing changes)."""
    calls = {"n": 0}

    def flaky(comp, **kw):
        calls["n"] += 1
        if calls["n"] == failing_call:
            raise RuntimeError("transient")
        from repro.core.engine import run_traces
        return run_traces(comp, **kw)

    svc = SNPTraceService(batch_size=2, step_bucket=4, runner=flaky)
    tickets = [svc.submit(TraceRequest(PI, steps=3, policy="random", seed=s))
               for s in range(4)]   # 2 chunks of 2
    with pytest.raises(RuntimeError, match="transient"):
        svc.drain()
    assert svc.pending == 4          # nothing was lost, even served chunks
    results = svc.drain()            # retry serves everything
    assert svc.pending == 0
    assert set(results) == set(tickets)
    for s, t in enumerate(tickets):
        c, _, _, *_ = run_trace(PI, steps=3, policy="random", seed=s)
        np.testing.assert_array_equal(results[t].configs, np.asarray(c))


def test_mixed_step_counts_share_one_group_and_one_call():
    svc = SNPTraceService(batch_size=8, step_bucket=16)
    reqs = [TraceRequest(PI, steps=s, policy="random", seed=s)
            for s in (1, 7, 13)]
    tickets = [svc.submit(r) for r in reqs]
    results = svc.drain()
    assert svc.num_device_calls == 1   # one group, one padded batch
    for t, r in zip(tickets, reqs):
        got = results[t]
        assert got.configs.shape[0] == r.steps   # sliced to the request
        c, e, a, *_ = run_trace(PI, steps=r.steps, policy=r.policy, seed=r.seed)
        np.testing.assert_array_equal(got.configs, np.asarray(c))
        np.testing.assert_array_equal(got.emissions, np.asarray(e))
        np.testing.assert_array_equal(got.alive, np.asarray(a))


def test_compile_cache_evicts_at_cap_and_stays_correct():
    systems = [random_system(6, 2, 0.4, seed=s) for s in range(3)]
    svc = SNPTraceService(batch_size=2, compile_cache_cap=2)
    tickets = [svc.submit(TraceRequest(s, steps=4, seed=1)) for s in systems]
    assert len(svc._compile_cache) == 2          # third compile evicted one
    assert systems[0] not in svc._compile_cache  # FIFO: oldest went first
    # resubmitting the evicted system recompiles under the cap
    t_again = svc.submit(TraceRequest(systems[0], steps=4, seed=1))
    assert len(svc._compile_cache) == 2
    results = svc.drain()
    for sysm, t in zip(systems + [systems[0]], tickets + [t_again]):
        c, _, _, *_ = run_trace(sysm, steps=4, seed=1)
        np.testing.assert_array_equal(results[t].configs, np.asarray(c))


def test_precompiled_systems_bypass_the_compile_cache():
    comp = compile_system(PI)
    svc = SNPTraceService(batch_size=2, compile_cache_cap=1)
    t = svc.submit(TraceRequest(comp, steps=4, seed=2))
    assert len(svc._compile_cache) == 0
    got = svc.drain()[t]
    c, _, _, *_ = run_trace(comp, steps=4, seed=2)
    np.testing.assert_array_equal(got.configs, np.asarray(c))


# ---------------------------------------------------------------------------
# pluggable runner: mesh-sharded flushes
# ---------------------------------------------------------------------------

def test_mesh_runner_service_matches_default_runner():
    mesh = Mesh(np.array(jax.devices()), ("traces",))
    reqs = _mixed_requests()
    plain = SNPTraceService(batch_size=8, step_bucket=8)
    tickets = [plain.submit(r) for r in reqs]
    expected = plain.drain()
    svc = SNPTraceService(batch_size=8, step_bucket=8,
                          runner=make_trace_runner(mesh=mesh))
    tickets2 = [svc.submit(r) for r in reqs]
    results = svc.drain()
    for t, t2 in zip(tickets, tickets2):
        _assert_result_equal(expected[t], results[t2])


def test_make_trace_runner_without_mesh_is_run_traces():
    from repro.core.engine import run_traces
    assert make_trace_runner() is run_traces


def test_async_mesh_service_end_to_end():
    """The launch-path composition: async drain + mesh runner together."""
    mesh = Mesh(np.array(jax.devices()), ("traces",))
    with SNPTraceService(batch_size=4, step_bucket=8, async_mode=True,
                         max_delay_ms=10,
                         runner=make_trace_runner(mesh=mesh)) as svc:
        futs = [svc.submit(TraceRequest(PI, steps=6, policy="random", seed=s))
                for s in range(6)]
        for s, fut in enumerate(futs):
            got = fut.result(timeout=TIMEOUT)
            c, e, _, *_ = run_trace(PI, steps=6, policy="random", seed=s)
            np.testing.assert_array_equal(got.configs, np.asarray(c))
            np.testing.assert_array_equal(got.emissions, np.asarray(e))


def test_submissions_from_many_threads_all_resolve():
    """Concurrent producers: every future resolves to its own trajectory."""
    with SNPTraceService(batch_size=8, step_bucket=8, async_mode=True,
                         max_delay_ms=5) as svc:
        out = {}

        def producer(seed):
            fut = svc.submit(
                TraceRequest(PI, steps=4, policy="random", seed=seed))
            out[seed] = fut.result(timeout=TIMEOUT)

        threads = [threading.Thread(target=producer, args=(s,))
                   for s in range(12)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    for seed, got in out.items():
        c, _, _, *_ = run_trace(PI, steps=4, policy="random", seed=seed)
        np.testing.assert_array_equal(got.configs, np.asarray(c))
