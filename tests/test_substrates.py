"""Substrate tests: optimizer/schedules, train step (microbatching,
compression), data pipeline determinism/resume, checkpointing (atomic,
verify, async, reshard), fault-tolerant supervisor, straggler policy."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, data_iterator, dedup_batch, make_batch
from repro.models import init_params
from repro.runtime import (FailureInjector, StragglerConfig,
                           StragglerDetector, Supervisor, SupervisorConfig,
                           choose_mesh_shape, rebalance_shares)
from repro.train import (AdamWConfig, TrainState, init_train_state,
                         make_schedule, make_train_step)
from repro.train.compression import (compress_grads, dequantize_int8,
                                     ef_init, quantize_int8)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("smollm-360m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _batch(cfg, B=4, S=16, step=0):
    return {k: jnp.asarray(v) for k, v in
            make_batch(cfg, DataConfig(seed=7), step=step, shard=0,
                       batch=B, seq_len=S).items()}


# --------------------------------------------------------------------------
# optimizer / schedules
# --------------------------------------------------------------------------

def test_schedules():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) < 1e-6

    wsd = make_schedule(AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                    schedule="wsd", decay_frac=0.2))
    assert abs(float(wsd(jnp.asarray(50))) - 1.0) < 1e-6   # stable plateau
    assert float(wsd(jnp.asarray(99))) < 0.2               # decay tail


def test_train_loss_decreases(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50,
                      grad_clip=1.0)
    step = make_train_step(cfg, opt, remat="none")
    state = init_train_state(params, opt)
    batch = _batch(cfg)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert np.isfinite(losses).all()


def test_microbatched_grads_match_full(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch(cfg, B=4)
    s1 = init_train_state(params, opt)
    s2 = init_train_state(params, opt)
    step1 = make_train_step(cfg, opt, microbatches=1, remat="none")
    step2 = make_train_step(cfg, opt, microbatches=2, remat="none")
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    # same data => same (averaged) update up to accumulation-order noise
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-3)


def test_remat_matches_no_remat(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch(cfg)
    outs = []
    for remat in ("none", "full", "dots"):
        s = init_train_state(params, opt)
        s, m = make_train_step(cfg, opt, remat=remat)(s, batch)
        outs.append(float(m["loss"]))
    assert max(outs) - min(outs) < 1e-4, outs


# --------------------------------------------------------------------------
# compression
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 2000))
def test_int8_quantization_roundtrip_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * rng.uniform(0.01, 10))
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape)
    # block-wise symmetric int8: error <= scale/2 = max|block| / 254
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 254 + 1e-7


def test_error_feedback_preserves_gradient_mass():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = ef_init(g)
    total_applied = jnp.zeros_like(g["w"])
    for _ in range(8):
        applied, ef = compress_grads(g, ef)
        total_applied = total_applied + applied["w"]
    # after k steps, sum(applied) ≈ k*g with residual bounded by one quantum
    err = np.abs(np.asarray(total_applied - 8 * g["w"]))
    assert err.max() < float(jnp.abs(g["w"]).max()) / 50


def test_compressed_training_still_converges(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    step = make_train_step(cfg, opt, remat="none", compression=True)
    state = init_train_state(params, opt, compression=True)
    batch = _batch(cfg)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.95


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_sharded(tiny):
    cfg, _ = tiny
    d = DataConfig(seed=3)
    a = make_batch(cfg, d, step=5, shard=0, batch=4, seq_len=32)
    b = make_batch(cfg, d, step=5, shard=0, batch=4, seq_len=32)
    c = make_batch(cfg, d, step=5, shard=1, batch=4, seq_len=32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < cfg.vocab_size
    assert (a["labels"][..., -1] == -1).all()


def test_data_resume_bit_identical(tiny):
    cfg, _ = tiny
    d = DataConfig(seed=3)
    it = data_iterator(cfg, d, shard=0, batch=2, seq_len=16)
    ref = {s: b for s, b in (next(it) for _ in range(6))}
    it2 = data_iterator(cfg, d, shard=0, batch=2, seq_len=16, start_step=3)
    s, b = next(it2)
    assert s == 3
    np.testing.assert_array_equal(ref[3]["tokens"], b["tokens"])


def test_dedup_batch():
    t = np.array([[1, 2, 3], [4, 5, 6], [1, 2, 3], [7, 8, 9]])
    np.testing.assert_array_equal(dedup_batch(t),
                                  [True, True, False, True])


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, {"params": params, "x": jnp.arange(5)})
    assert latest_step(d) == 7
    template = {"params": jax.tree.map(np.zeros_like, params),
                "x": np.zeros(5, np.int32)}
    tree, step, _ = restore_checkpoint(d, template)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    path = save_checkpoint(d, 1, {"w": jnp.arange(32, dtype=jnp.float32)})
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["w"][3] = 999.0
    np.savez(npz, **data)
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(d, {"w": np.zeros(32, np.float32)})


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.zeros(4)})
    # simulate a crashed writer: a stale .tmp dir must be invisible
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(s, {"w": jnp.full(4, s)})
    ck.wait()
    assert latest_step(d) == 3
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert steps == [2, 3]   # gc keeps last 2


# --------------------------------------------------------------------------
# fault tolerance / elastic / straggler
# --------------------------------------------------------------------------

def test_supervisor_recovers_from_injected_failures(tmp_path, tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    ckpt_dir = str(tmp_path / "sup")
    step_fn = make_train_step(cfg, opt, remat="none")

    def make_step(restore_step):
        state = init_train_state(params, opt)
        if restore_step is not None:
            template = jax.tree.map(np.asarray, state)
            state, s, _ = restore_checkpoint(ckpt_dir, template,
                                             step=restore_step)
            state = jax.tree.map(jnp.asarray, state)
            return state, step_fn, s
        return state, step_fn, 0

    sup = Supervisor(
        SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=5, max_restarts=3),
        make_step,
        data_for=lambda s: _batch(cfg, step=s),
        injector=FailureInjector(fail_at_steps=(7, 13)),
    )
    state, report = sup.run(20)
    assert report["final_step"] == 20
    assert report["restarts"] == 2
    assert int(state.step) >= 15   # restored at 5-multiples then advanced


def test_supervisor_gives_up_after_max_restarts(tmp_path, tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    step_fn = make_train_step(cfg, opt, remat="none")

    def make_step(restore_step):
        return init_train_state(params, opt), step_fn, 0

    sup = Supervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path / "s2"), ckpt_every=100,
                         max_restarts=2),
        make_step, data_for=lambda s: _batch(cfg, step=s),
        injector=FailureInjector(fail_at_steps=(1, 1, 1, 1)),
    )
    # failing at step 1 forever (no checkpoint before it): must give up
    sup.injector.remaining = {1}

    class Always:
        def check(self, step):
            if step == 1:
                raise RuntimeError("hard failure")
    sup.injector = Always()
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(5)


def test_choose_mesh_shape():
    assert choose_mesh_shape(256, 16) == (16, 16)
    assert choose_mesh_shape(512, 16, pod_axis=2) == (2, 16, 16)
    assert choose_mesh_shape(384, 16, pod_axis=2) == (2, 12, 16)
    assert choose_mesh_shape(240, 16) == (15, 16)
    with pytest.raises(ValueError):
        choose_mesh_shape(8, 16)


def test_straggler_detector_and_rebalance():
    det = StragglerDetector(StragglerConfig(patience=2, evict_after=3),
                            num_hosts=4)
    # host 2 persistently 3x slower
    decision = {}
    for _ in range(6):
        decision = det.observe([1.0, 1.0, 3.0, 1.0])
    assert decision["stragglers"] == [2]
    assert decision["evict"] == [2]
    shares = rebalance_shares(4, 4, [2], slowdown=2.0)
    assert sum(shares) == 16 and shares[2] == 2
    # no straggler -> unchanged
    assert rebalance_shares(4, 4, []) == [4, 4, 4, 4]
