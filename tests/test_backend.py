"""Step-backend layer tests: registry, cross-backend equivalence end-to-end
through every consumer (explore, run_trace, run_traces), batched trace
serving, and the snp_service batching front end.

Equivalence tests are **registry-driven**: they parametrize over
``available_backends()`` with ``"ref"`` as the oracle, so any newly
registered backend (sparse today, whatever comes next) is oracle-checked
through every consumer with zero test changes.  Each backend compiles its
own encoding via ``backend.compile`` — exactly the consumer code path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import conftest
from repro.core import (available_backends, compile_system, explore,
                        get_backend, paper_pi, register_backend, run_trace,
                        run_traces)
from repro.core.backend import (PallasBackend, RefBackend, SparseBackend,
                                SparsePallasBackend)
from repro.core.generators import nd_chain
from repro.serve.snp_service import SNPTraceService, TraceRequest

# consumer-equivalence workloads: the cheap subset of the shared fixtures
SYSTEMS = {k: conftest.EQUIV_SYSTEMS[k]
           for k in ("paper-pi", "nd-chain-4", "random-16")}

NON_REF = [b for b in available_backends() if b != "ref"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_lookup():
    assert {"ref", "pallas", "sparse", "sparse_pallas"} \
        <= set(available_backends())
    assert get_backend("ref") == RefBackend()
    assert get_backend("pallas").name == "pallas"
    assert get_backend("sparse") == SparseBackend()
    assert get_backend("sparse_pallas").name == "sparse_pallas"
    # instances pass through unchanged
    be = PallasBackend(block_t=16)
    assert get_backend(be) is be
    with pytest.raises(ValueError, match="unknown step backend"):
        get_backend("no-such-backend")
    with pytest.raises(ValueError, match="already registered"):
        register_backend(RefBackend())


def test_backend_metadata():
    ref, pal = get_backend("ref"), get_backend("pallas")
    sp, spp = get_backend("sparse"), get_backend("sparse_pallas")
    for b in (ref, pal, sp, spp):
        assert b.supports_nd_batch
    assert ref.pad_multiple == 1 and sp.pad_multiple == 1
    assert pal.pad_multiple == pal.block_b
    assert spp.pad_multiple == spp.block_b
    assert ref.materializes_spiking
    assert not any(b.materializes_spiking for b in (pal, sp, spp))


def test_sparse_backends_reject_dense_compilation():
    comp = compile_system(paper_pi(True))
    cfgs = jnp.asarray([[2, 1, 1]], jnp.int32)
    for name in ("sparse", "sparse_pallas"):
        with pytest.raises(TypeError, match="CompiledSparseSNP"):
            get_backend(name).expand(cfgs, comp, 8)


@pytest.mark.parametrize("name", NON_REF)
def test_backends_agree_on_step_out(name):
    system = paper_pi(True)
    cfgs = jnp.asarray([[2, 1, 1], [2, 1, 2], [0, 0, 0]], jnp.int32)
    ref, be = get_backend("ref"), get_backend(name)
    a = ref.expand(cfgs, ref.compile(system), 8)
    b = be.expand(cfgs, be.compile(system), 8)
    conftest.assert_same_step(a, b)
    assert b.spiking is None  # only ref materializes S


# ---------------------------------------------------------------------------
# equivalence through the consumers (registry-driven)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", NON_REF)
@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_explore_backend_equivalence(name, backend):
    system, T = SYSTEMS[name]
    kw = dict(max_steps=6, frontier_cap=128, visited_cap=1024, max_branches=T)
    ref = explore(system, backend="ref", **kw)
    got = explore(system, backend=backend, **kw)
    # identical archives *in discovery order*, identical flags
    np.testing.assert_array_equal(ref.configs, got.configs)
    assert ref.num_discovered == got.num_discovered
    assert ref.steps == got.steps
    assert (ref.branch_overflow, ref.frontier_overflow, ref.visited_overflow) \
        == (got.branch_overflow, got.frontier_overflow, got.visited_overflow)


@pytest.mark.parametrize("backend", NON_REF)
@pytest.mark.parametrize("policy", ["first", "random"])
def test_run_trace_backend_equivalence(policy, backend):
    for name, (system, T) in sorted(SYSTEMS.items()):
        ref = run_trace(system, steps=10, policy=policy, seed=11,
                        max_branches=T, backend="ref")
        got = run_trace(system, steps=10, policy=policy, seed=11,
                        max_branches=T, backend=backend)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_explore_accepts_backend_instance():
    system = paper_pi(True)
    for be in (PallasBackend(block_b=4, block_t=8, block_n=8),
               SparsePallasBackend(block_b=4, block_t=8)):
        res = explore(system, max_steps=4, frontier_cap=32, visited_cap=256,
                      max_branches=16, backend=be)
        ref = explore(system, max_steps=4, frontier_cap=32, visited_cap=256,
                      max_branches=16)
        np.testing.assert_array_equal(res.configs, ref.configs)


def test_explore_loop_is_on_device_while_loop():
    """The BFS must be a single lax.while_loop: tracing the loop body must
    happen once, with a traced (non-concrete) frontier_n — i.e. no host
    Python loop peeking at per-step scalars."""
    from repro.core import engine

    comp = compile_system(paper_pi(True))
    state = engine._init_state(comp, 32, 256)
    traced = jax.make_jaxpr(
        lambda s: engine._explore_loop(s, comp, 8, 16, get_backend("ref"))
    )(state)
    assert "while" in str(traced)


# ---------------------------------------------------------------------------
# batched trace serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["first", "random"])
def test_run_traces_matches_per_seed_run_trace(policy):
    comp = compile_system(paper_pi(True))
    seeds = [0, 1, 7, 42, 1234]
    cfgs, emis, alive, *_ = run_traces(comp, steps=12, seeds=seeds, policy=policy)
    assert cfgs.shape == (len(seeds), 12, comp.num_neurons)
    for i, s in enumerate(seeds):
        c, e, a, *_ = run_trace(comp, steps=12, policy=policy, seed=s)
        np.testing.assert_array_equal(np.asarray(cfgs[i]), np.asarray(c))
        np.testing.assert_array_equal(np.asarray(emis[i]), np.asarray(e))
        np.testing.assert_array_equal(np.asarray(alive[i]), np.asarray(a))


@pytest.mark.parametrize("backend", NON_REF)
def test_run_traces_backend_equivalence(backend):
    system = nd_chain(4)
    seeds = list(range(6))
    ref = run_traces(system, steps=8, seeds=seeds, policy="random",
                     max_branches=32, backend="ref")
    got = run_traces(system, steps=8, seeds=seeds, policy="random",
                     max_branches=32, backend=backend)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_traces_rejects_bad_input():
    comp = compile_system(paper_pi(True))
    with pytest.raises(ValueError, match="policy"):
        run_traces(comp, steps=4, seeds=[0], policy="greedy")
    with pytest.raises(ValueError, match="1-D"):
        run_traces(comp, steps=4, seeds=[[0, 1]])


# ---------------------------------------------------------------------------
# snp_service
# ---------------------------------------------------------------------------

def test_service_batches_heterogeneous_requests():
    svc = SNPTraceService(batch_size=8, step_bucket=8)
    pi, chain = paper_pi(True), nd_chain(4)
    reqs = {
        "a": TraceRequest(pi, steps=5, policy="random", seed=7),
        "b": TraceRequest(pi, steps=11, policy="random", seed=9),
        "c": TraceRequest(pi, steps=6, policy="first"),
        "d": TraceRequest(chain, steps=4, policy="random", seed=1,
                          max_branches=32),
    }
    tickets = {k: svc.submit(r) for k, r in reqs.items()}
    assert svc.pending == 4
    results = svc.drain()
    assert svc.pending == 0
    # three groups: (pi, random), (pi, first), (chain, random)
    assert svc.num_device_calls == 3
    assert svc.num_traces_served == 4
    for k, r in reqs.items():
        got = results[tickets[k]]
        c, e, a, *_ = run_trace(r.system, steps=r.steps, policy=r.policy,
                            seed=r.seed, max_branches=r.max_branches)
        assert got.configs.shape == (r.steps, 4 if k == "d" else 3)
        np.testing.assert_array_equal(got.configs, np.asarray(c))
        np.testing.assert_array_equal(got.emissions, np.asarray(e))
        np.testing.assert_array_equal(got.alive, np.asarray(a))


def test_service_serves_256_trace_batch_in_one_call():
    svc = SNPTraceService(batch_size=256, step_bucket=8)
    pi = paper_pi(True)
    tickets = [svc.submit(TraceRequest(pi, steps=8, policy="random", seed=s))
               for s in range(256)]
    results = svc.drain()
    assert svc.num_device_calls == 1          # one jitted run_traces launch
    assert len(results) == 256
    # spot-check a few against solo traces
    for s in (0, 17, 255):
        c, e, _, *_ = run_trace(pi, steps=8, policy="random", seed=s)
        np.testing.assert_array_equal(results[tickets[s]].configs,
                                      np.asarray(c))
        np.testing.assert_array_equal(results[tickets[s]].emissions,
                                      np.asarray(e))


def test_service_chunks_oversized_groups_and_pads_short_ones():
    svc = SNPTraceService(batch_size=4, step_bucket=4)
    pi = paper_pi(True)
    tickets = [svc.submit(TraceRequest(pi, steps=3, seed=s, policy="random"))
               for s in range(6)]
    results = svc.drain()
    assert svc.num_device_calls == 2          # 6 requests / batch_size 4
    for s in range(6):
        c, _, _, *_ = run_trace(pi, steps=3, policy="random", seed=s)
        np.testing.assert_array_equal(results[tickets[s]].configs,
                                      np.asarray(c))


def test_service_with_sparse_backend_matches_ref_service():
    svc = SNPTraceService(batch_size=4, step_bucket=4, backend="sparse")
    pi = paper_pi(True)
    t = svc.submit(TraceRequest(pi, steps=6, policy="random", seed=3))
    got = svc.drain()[t]
    c, e, a, *_ = run_trace(pi, steps=6, policy="random", seed=3)
    np.testing.assert_array_equal(got.configs, np.asarray(c))
    np.testing.assert_array_equal(got.emissions, np.asarray(e))


def test_service_validates_requests():
    with pytest.raises(ValueError, match="policy"):
        TraceRequest(paper_pi(True), steps=4, policy="greedy")
    with pytest.raises(ValueError, match="steps"):
        TraceRequest(paper_pi(True), steps=0)
    svc = SNPTraceService(batch_size=2, max_steps=16)
    with pytest.raises(ValueError, match="max_steps"):
        svc.submit(TraceRequest(paper_pi(True), steps=64))
