"""Property tests for the delayed-semantics tier.

Three families, all on hypothesis-generated small systems:

* **zero-delay collapse** — an all-zero-delay system stepped under
  ``semantics="delays"`` matches the delay-free path configuration-for-
  configuration (spikes slice identical, countdown/pending identically 0);
* **backend × encoding agreement** — every lowering of the delayed step
  (ref dense / sparse ELL / sparse hybrid / dense Pallas / sparse Pallas /
  hybrid Pallas) produces the same successor set bit-for-bit, from
  arbitrary (also unreachable) delayed states;
* **closed-neuron invariant** — a neuron whose countdown stays nonzero
  after the step (no reopen) keeps its spike count: it cannot fire,
  cannot receive, and its countdown/pending evolve deterministically.

Plus a hypothesis differential against the pure-Python oracle
(:mod:`tests.oracle`) from random delayed states — not just the initial
configuration the BFS differential in ``test_delays_oracle.py`` starts at.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

import oracle  # noqa: E402
from repro.core import (SNPSystem, Rule, compile_system,  # noqa: E402
                        compile_system_sparse, delayed_next_configs,
                        sparse_delayed_next_configs, with_delays)
from repro.kernels.snp_step.ops import snp_step  # noqa: E402
from repro.kernels.snp_step.sparse_ops import snp_step_sparse  # noqa: E402

T = 128  # max_branches everywhere here


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

@st.composite
def delayed_systems(draw):
    m = draw(st.integers(1, 4))
    n_rules = draw(st.integers(1, 6))
    rules = []
    for _ in range(n_rules):
        neuron = draw(st.integers(0, m - 1))
        consume = draw(st.integers(1, 3))
        base = draw(st.integers(consume, consume + 2))
        period = draw(st.sampled_from([0, 0, 1, 2]))
        produce = draw(st.integers(0, 2))
        covering = draw(st.booleans())
        delay = draw(st.sampled_from([0, 0, 1, 2, 3]))
        rules.append(Rule(neuron=neuron, consume=consume, produce=produce,
                          regex_base=base, regex_period=period,
                          covering=covering, delay=delay))
    pairs = [(i, j) for i in range(m) for j in range(m) if i != j]
    syn = tuple(p for p in pairs if draw(st.booleans()))
    init = tuple(draw(st.integers(0, 3)) for _ in range(m))
    return SNPSystem(num_neurons=m, initial_spikes=init, rules=tuple(rules),
                     synapses=syn, output_neuron=m - 1, name="hyp-delays")


@st.composite
def delayed_states(draw, m):
    """An arbitrary 3m state row — including states a run could never
    reach (pending without countdown): the lowerings must agree on the
    full state space, not just the reachable slice."""
    spikes = tuple(draw(st.integers(0, 3)) for _ in range(m))
    cd = tuple(draw(st.integers(0, 3)) for _ in range(m))
    pd = tuple(draw(st.integers(0, 2)) for _ in range(m))
    return spikes + cd + pd


@st.composite
def systems_and_states(draw):
    system = draw(delayed_systems())
    state = draw(delayed_states(system.num_neurons))
    return system, state


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _rows(configs, valid, emissions):
    """(successor row, emission) pairs of the valid branches — batched or
    not, any state width."""
    configs = np.asarray(configs).reshape(-1, configs.shape[-1])
    valid = np.asarray(valid).reshape(-1)
    emissions = np.asarray(emissions).reshape(-1)
    return {(tuple(int(v) for v in configs[t]), int(emissions[t]))
            for t in np.nonzero(valid)[0]}


def all_lowerings(system, state):
    """Successor sets of one delayed step through every lowering."""
    cfg = jnp.asarray(state, jnp.int32)
    batch = cfg[None, :]
    comp_d = compile_system(system, semantics="delays")
    comp_e = compile_system_sparse(system, semantics="delays")
    comp_h = compile_system_sparse(system, hub_threshold=1,
                                   semantics="delays")
    out = {}
    o = delayed_next_configs(cfg, comp_d, T)
    out["ref"] = _rows(o.configs, o.valid, o.emissions)
    for name, comp in (("sparse/ell", comp_e), ("sparse/hybrid", comp_h)):
        o = sparse_delayed_next_configs(cfg, comp, T)
        out[name] = _rows(o.configs, o.valid, o.emissions)
    c, v, e, _ = snp_step(batch, comp_d, max_branches=T)
    out["pallas"] = _rows(c, v, e)
    for name, comp in (("sparse_pallas/ell", comp_e),
                       ("sparse_pallas/hybrid", comp_h)):
        c, v, e, _ = snp_step_sparse(batch, comp, max_branches=T)
        out[name] = _rows(c, v, e)
    return out


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(systems_and_states())
def test_backend_encoding_matrix_agreement(sys_state):
    system, state = sys_state
    outs = all_lowerings(system, state)
    ref = outs.pop("ref")
    for name, got in outs.items():
        assert got == ref, name


@settings(max_examples=40, deadline=None)
@given(systems_and_states())
def test_successors_match_oracle_from_arbitrary_states(sys_state):
    system, state = sys_state
    m = system.num_neurons
    tri = (state[:m], state[m:2 * m], state[2 * m:])
    want = {(oracle.flatten(s), e) for s, e in oracle.successors(tri, system)}
    o = delayed_next_configs(jnp.asarray(state, jnp.int32),
                             compile_system(system, semantics="delays"), T)
    assert _rows(o.configs, o.valid, o.emissions) == want


@settings(max_examples=30, deadline=None)
@given(delayed_systems())
def test_zero_delay_is_bit_identical_to_no_delays(system):
    sys0 = with_delays(system, 0)
    cfg = jnp.asarray(system.initial_spikes, jnp.int32)
    m = system.num_neurons
    from repro.core.semantics import next_configs
    base = next_configs(cfg, compile_system(system), T)
    want = _rows(base.configs, base.valid, base.emissions)
    state = jnp.concatenate([cfg, jnp.zeros(2 * m, jnp.int32)])
    o = delayed_next_configs(state,
                             compile_system(sys0, semantics="delays"), T)
    got = _rows(o.configs, o.valid, o.emissions)
    # spikes slice identical, countdown/pending identically zero
    assert {(r[:m], e) for r, e in got} == want
    assert all(not any(r[m:]) for r, _ in got)


@settings(max_examples=40, deadline=None)
@given(systems_and_states())
def test_closed_neuron_invariant(sys_state):
    """While a neuron's countdown stays nonzero it neither fires nor
    receives: spikes unchanged, countdown decremented (or freshly set),
    pending untouched — on *every* successor branch."""
    system, state = sys_state
    m = system.num_neurons
    spikes, cd = state[:m], state[m:2 * m]
    o = delayed_next_configs(jnp.asarray(state, jnp.int32),
                             compile_system(system, semantics="delays"), T)
    rows = _rows(o.configs, o.valid, o.emissions)
    for row, _ in rows:
        sp2, cd2, pd2 = row[:m], row[m:2 * m], row[2 * m:]
        for j in range(m):
            if cd[j] > 1:  # closed before, still closed after (no reopen)
                assert sp2[j] == spikes[j]
                assert cd2[j] == cd[j] - 1
                assert pd2[j] == state[2 * m + j]
