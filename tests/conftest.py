"""Shared fixtures for the cross-backend equivalence suites.

The kernel-lowering registry (``StepBackend.supported_encodings``,
semantics-aware since the delays tier) is the single source of truth for
which ``(backend, encoding, semantics)`` cells exist.  The
:func:`lowering_cell` fixture walks that declaration, so a newly
registered backend, encoding, or semantics tier is oracle-checked by the
equivalence suites with zero test changes — the consolidation of the
per-file ``SYSTEMS``/``_assert_same_step`` copies that
``test_backend.py`` / ``test_kernel_lowering.py`` / ``test_sparse.py``
used to carry (``import conftest`` to reach the helpers from a test
module).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import SystemPlan, available_backends, get_backend, paper_pi
from repro.core.generators import (nd_chain, power_law, random_system,
                                   ring_lattice, with_delays)

# Shared equivalence workloads: (system, max_branches).  Suites pick the
# subset that matches their cost budget by name.
EQUIV_SYSTEMS = {
    "paper-pi": (paper_pi(True), 16),
    "nd-chain-4": (nd_chain(4), 32),
    "random-16": (random_system(16, 2, 0.2, seed=4), 32),
    "random-17": (random_system(17, 3, 0.3, seed=3), 32),
    "ring-lattice-12": (ring_lattice(12, 3, seed=1), 16),
    "power-law-40": (power_law(40, 3, seed=3), 16),
}

# Concrete single-device plans per declared encoding.  hub_threshold=1 is
# the hub-tail-only extreme: the entire hub in-adjacency rides the COO
# segment-sum stage.
ENCODING_PLANS = {
    "dense": (SystemPlan(encoding="dense"),),
    "ell": (SystemPlan(encoding="ell"),),
    "hybrid": (SystemPlan(encoding="hybrid", hub_threshold=1),
               SystemPlan(encoding="hybrid", hub_threshold=4)),
}

SEMANTICS = ("no_delays", "delays")


def delayed_variant(system):
    """The delay pattern the delayed equivalence cells run under: mixed
    per-rule delays d = k mod 3 (some instant, some closing)."""
    return with_delays(system, lambda k, r: k % 3)


def random_states(system, semantics, batch, seed, high=4):
    """A batch of random state rows of the right width for ``semantics``
    — under delays, arbitrary countdown/pending too (the lowerings must
    agree on the whole state space, not just the reachable slice)."""
    rng = np.random.default_rng(seed)
    m = system.num_neurons
    parts = [rng.integers(0, high, size=(batch, m))]
    if semantics == "delays":
        parts += [rng.integers(0, 3, size=(batch, m)),
                  rng.integers(0, 3, size=(batch, m))]
    return np.concatenate(parts, axis=1).astype(np.int32)


def lowering_cells():
    """Every realizable single-device ``(backend, plan)`` cell of the
    registry, across both semantics tiers."""
    cells = []
    for semantics in SEMANTICS:
        for name in sorted(available_backends()):
            be = get_backend(name)
            for enc in be.supported_encodings(semantics=semantics):
                for plan in ENCODING_PLANS.get(enc, ()):
                    p = dataclasses.replace(plan, semantics=semantics)
                    tag = f"{semantics}-{name}-{enc}"
                    if enc == "hybrid":
                        tag += f"-h{plan.hub_threshold}"
                    cells.append(pytest.param((name, p), id=tag))
    return cells


@pytest.fixture(params=lowering_cells())
def lowering_cell(request):
    """(backend name, concrete SystemPlan) — one registry cell."""
    return request.param


def assert_same_step(a, b):
    """Bit-identity of two expanded steps on their valid entries."""
    va, vb = np.asarray(a.valid), np.asarray(b.valid)
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(np.asarray(a.overflow),
                                  np.asarray(b.overflow))
    np.testing.assert_array_equal(
        np.where(va[..., None], np.asarray(a.configs), 0),
        np.where(vb[..., None], np.asarray(b.configs), 0))
    np.testing.assert_array_equal(
        np.where(va, np.asarray(a.emissions), 0),
        np.where(vb, np.asarray(b.emissions), 0))
