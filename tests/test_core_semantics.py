"""Unit + property tests for the SNP matrix semantics.

The property tests compare the vectorized JAX semantics against a
deliberately naive, independent pure-Python reference (itertools-based
enumeration, dict-based BFS) on randomly generated small systems.
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.engine import explore, successor_set
from repro.core.hashing import config_hash
from repro.core.matrix import compile_system
from repro.core.semantics import branch_info, next_configs, spiking_vectors
from repro.core.system import Rule, SNPSystem, paper_pi


# ---------------------------------------------------------------------------
# Pure-Python reference semantics (independent implementation)
# ---------------------------------------------------------------------------

def py_applicable(spikes: int, r: Rule) -> bool:
    if spikes < max(r.regex_base, r.consume):
        return False
    if r.covering:
        return True
    if r.regex_period > 0:
        return (spikes - r.regex_base) % r.regex_period == 0
    return spikes == r.regex_base


def py_successors(cfg, system: SNPSystem):
    """Set of (successor tuple, emission) via brute-force product."""
    per_neuron = []
    for i in range(system.num_neurons):
        apps = [r for r in system.rules
                if r.neuron == i and py_applicable(cfg[i], r)]
        per_neuron.append(apps if apps else [None])
    if all(c == [None] for c in per_neuron):
        return set()
    syn = set(system.synapses)
    out = set()
    for combo in itertools.product(*per_neuron):
        nxt = list(cfg)
        emis = 0
        for r in combo:
            if r is None:
                continue
            nxt[r.neuron] -= r.consume
            if r.produce > 0:
                for j in range(system.num_neurons):
                    if (r.neuron, j) in syn:
                        nxt[j] += r.produce
                if r.neuron == system.output_neuron:
                    emis += r.produce
        out.add((tuple(nxt), emis))
    return out


def py_bfs(system: SNPSystem, max_steps: int):
    seen = {tuple(system.initial_spikes)}
    frontier = [tuple(system.initial_spikes)]
    for _ in range(max_steps):
        nxt = []
        for cfg in frontier:
            for succ, _ in py_successors(cfg, system):
                if succ not in seen:
                    seen.add(succ)
                    nxt.append(succ)
        frontier = nxt
        if not frontier:
            break
    return seen


# ---------------------------------------------------------------------------
# Hypothesis strategy for small random systems
# ---------------------------------------------------------------------------

@st.composite
def snp_systems(draw):
    m = draw(st.integers(1, 4))
    n_rules = draw(st.integers(1, 6))
    rules = []
    for _ in range(n_rules):
        neuron = draw(st.integers(0, m - 1))
        consume = draw(st.integers(1, 3))
        base = draw(st.integers(consume, consume + 2))
        period = draw(st.sampled_from([0, 0, 1, 2]))
        produce = draw(st.integers(0, 2))
        covering = draw(st.booleans())
        rules.append(Rule(neuron=neuron, consume=consume, produce=produce,
                          regex_base=base, regex_period=period,
                          covering=covering))
    pairs = [(i, j) for i in range(m) for j in range(m) if i != j]
    syn = tuple(p for p in pairs if draw(st.booleans()))
    init = tuple(draw(st.integers(0, 3)) for _ in range(m))
    return SNPSystem(num_neurons=m, initial_spikes=init, rules=tuple(rules),
                     synapses=syn, output_neuron=m - 1, name="hyp")


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(snp_systems())
def test_successors_match_python_reference(system):
    comp = compile_system(system)
    got = set(successor_set(comp, system.initial_spikes, max_branches=128))
    want = py_successors(tuple(system.initial_spikes), system)
    assert got == want


@settings(max_examples=25, deadline=None)
@given(snp_systems(), st.integers(1, 4))
def test_bfs_matches_python_reference(system, depth):
    comp = compile_system(system)
    res = explore(comp, max_steps=depth, frontier_cap=256, visited_cap=4096,
                  max_branches=128)
    assert not (res.branch_overflow or res.frontier_overflow
                or res.visited_overflow)
    got = {tuple(int(v) for v in row) for row in res.configs}
    assert got == py_bfs(system, depth)


@settings(max_examples=40, deadline=None)
@given(snp_systems())
def test_spiking_vector_invariants(system):
    """Each valid spiking vector fires exactly one applicable rule per
    live neuron; the count of valid branches equals Ψ; vectors are distinct."""
    comp = compile_system(system)
    cfg = jnp.asarray(system.initial_spikes, jnp.int32)
    info = branch_info(cfg, comp)
    S, valid, overflow = spiking_vectors(cfg, comp, 128)
    assert not bool(overflow)
    S, valid = np.asarray(S), np.asarray(valid)
    psi = int(np.prod([max(1, k) for k in np.asarray(info.choices)])) \
        if bool(info.alive) else 0
    assert valid.sum() == psi
    app = np.asarray(info.app)
    onehot = np.asarray(comp.neuron_onehot)
    seen = set()
    for t in np.nonzero(valid)[0]:
        s = S[t]
        assert ((s == 1) | (s == 0)).all()
        assert (s <= app).all()          # only applicable rules fire
        per_neuron = s @ onehot
        k = app @ onehot
        # exactly one rule per neuron that has any applicable rule
        np.testing.assert_array_equal(per_neuron, (k > 0).astype(per_neuron.dtype))
        key = tuple(s.tolist())
        assert key not in seen           # all enumerated vectors distinct
        seen.add(key)


@settings(max_examples=40, deadline=None)
@given(snp_systems())
def test_successor_configs_nonnegative(system):
    comp = compile_system(system)
    out = next_configs(jnp.asarray(system.initial_spikes, jnp.int32), comp, 128)
    cfgs, valid = np.asarray(out.configs), np.asarray(out.valid)
    assert (cfgs[valid] >= 0).all()


def test_branch_overflow_flagged():
    """A neuron chain with 2 applicable rules each => Ψ = 2^m > T flags."""
    m = 8
    rules = []
    for i in range(m):
        rules += [Rule(neuron=i, consume=1, produce=1, regex_base=1,
                       covering=True),
                  Rule(neuron=i, consume=1, produce=0, regex_base=1,
                       covering=True)]
    sys_ = SNPSystem(num_neurons=m, initial_spikes=(1,) * m,
                     rules=tuple(rules),
                     synapses=tuple((i, (i + 1) % m) for i in range(m)),
                     output_neuron=0, name="wide")
    comp = compile_system(sys_)
    _, valid, overflow = spiking_vectors(
        jnp.asarray(sys_.initial_spikes, jnp.int32), comp, 64)
    assert bool(overflow)
    assert int(np.asarray(valid).sum()) == 64  # first T branches still valid


def test_branch_enumeration_exact_at_boundary():
    """Ψ == T must not flag overflow."""
    rules = (Rule(0, 1, 1, 1, covering=True), Rule(0, 1, 0, 1, covering=True),
             Rule(1, 1, 1, 1, covering=True), Rule(1, 1, 0, 1, covering=True))
    sys_ = SNPSystem(2, (1, 1), rules, ((0, 1), (1, 0)), output_neuron=1)
    comp = compile_system(sys_)
    S, valid, overflow = spiking_vectors(jnp.array([1, 1], jnp.int32), comp, 4)
    assert not bool(overflow)
    assert int(np.asarray(valid).sum()) == 4


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 1000), min_size=3, max_size=3),
                min_size=2, max_size=50, unique_by=tuple))
def test_hash_no_collisions_on_distinct_configs(cfgs):
    arr = jnp.asarray(np.array(cfgs, dtype=np.int32))
    hi, lo = config_hash(arr)
    pairs = set(zip(np.asarray(hi).tolist(), np.asarray(lo).tolist()))
    assert len(pairs) == len(cfgs)


def test_hash_is_deterministic():
    c = jnp.arange(12, dtype=jnp.int32).reshape(2, 6)
    h1 = config_hash(c)
    h2 = config_hash(jnp.asarray(np.asarray(c)))
    np.testing.assert_array_equal(np.asarray(h1[0]), np.asarray(h2[0]))
    np.testing.assert_array_equal(np.asarray(h1[1]), np.asarray(h2[1]))


def test_forgetting_rules_produce_nothing():
    sys_ = SNPSystem(
        2, (2, 0),
        (Rule(neuron=0, consume=2, produce=0, regex_base=2),),
        ((0, 1),), output_neuron=1)
    comp = compile_system(sys_)
    succ = successor_set(comp, (2, 0))
    assert succ == [((0, 0), 0)]


def test_rule_validation():
    with pytest.raises(ValueError):
        Rule(neuron=0, consume=2, produce=1, regex_base=1)  # base < consume
    with pytest.raises(ValueError):
        SNPSystem(1, (0,), (Rule(0, 1, 1, 1),), ((0, 0),))  # self-synapse


def test_explore_on_batched_frontier_matches_unbatched():
    comp = compile_system(paper_pi(covering=True))
    small = explore(comp, max_steps=6, frontier_cap=4, visited_cap=512,
                    max_branches=16)
    big = explore(comp, max_steps=6, frontier_cap=256, visited_cap=512,
                  max_branches=16)
    # tiny frontier may overflow (re-expansion allowed) but discovered sets
    # at equal depth with no overflow must match
    if not small.frontier_overflow:
        assert set(small.as_strings()) == set(big.as_strings())
