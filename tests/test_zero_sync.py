"""Zero-host-sync distributed BFS + degree-weighted partitioning.

The fused ``lax.while_loop`` drivers must perform **no host transfer
between BFS levels**: without checkpointing, one device call covers the
whole run, and ``jax.transfer_guard_device_to_host("disallow")`` around
the call proves no implicit device→host readback happens before the
final (explicit ``jax.device_get``) readout.  The 8-device subprocess
variants re-check under a real mesh, including empty neuron shards
(m < ndev) and the overflow regime; the degree-weighted partition cells
assert both equivalence and the occupancy win it exists for.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import compile_sharded, explore, partition_stats, paper_pi
from repro.core.distributed import explore_distributed
from repro.core.generators import power_law, random_system
from repro.runtime.faults import FaultInjector
from repro.sharding import neuron_axis

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(ndev: int, body: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env, capture_output=True, text=True, timeout=600,
    )


# ---------------------------------------------------------------------------
# in-process (1-device mesh): the guards hold on any device count
# ---------------------------------------------------------------------------


def test_dense_explore_zero_host_transfers_inprocess():
    import jax
    comp_kw = dict(max_steps=12, frontier_cap=32, visited_cap=512,
                   max_branches=16)
    system = paper_pi(True)
    want = explore(system, dedup="sort", **comp_kw)
    with jax.transfer_guard_device_to_host("disallow"):
        got = explore_distributed(system, **comp_kw)
    assert {tuple(r) for r in got.configs} == \
        {tuple(r) for r in want.configs}


def test_dense_explore_is_one_device_call_without_checkpointing():
    """The whole BFS is ONE fused device program: the fault injector's
    device-call counter (bumped once per dispatched loop) must read
    exactly 1 after an un-checkpointed run."""
    inj = FaultInjector()
    explore_distributed(paper_pi(True), max_steps=12, frontier_cap=32,
                        visited_cap=512, max_branches=16,
                        fault_injector=inj)
    assert inj.calls == 1


def test_checkpointed_run_syncs_only_at_chunk_boundaries(tmp_path):
    inj = FaultInjector()
    r = explore_distributed(paper_pi(True), max_steps=12, frontier_cap=32,
                            visited_cap=512, max_branches=16,
                            checkpoint_dir=str(tmp_path),
                            checkpoint_every=4, fault_injector=inj)
    # ceil(steps / 4) chunks, one device call each
    assert inj.calls == -(-r.steps // 4)


# ---------------------------------------------------------------------------
# 8-device subprocess: both schemes under the transfer guard
# ---------------------------------------------------------------------------


def test_zero_host_sync_8dev_both_schemes():
    proc = _run(8, """
        import jax
        from repro.core import explore, paper_pi
        from repro.core.distributed import explore_distributed
        from repro.core.generators import power_law
        from repro.runtime.faults import FaultInjector
        from repro.sharding import neuron_axis

        assert len(jax.devices()) == 8
        kw = dict(max_steps=12, frontier_cap=64, visited_cap=512,
                  max_branches=16)
        system = paper_pi(True)       # m = 3 < 8: most shards are empty
        want = {tuple(r) for r in explore(system, dedup="sort",
                                          **kw).configs}

        inj = FaultInjector()
        with jax.transfer_guard_device_to_host("disallow"):
            rd = explore_distributed(system, fault_injector=inj, **kw)
        assert {tuple(r) for r in rd.configs} == want
        assert inj.calls == 1

        inj = FaultInjector()
        with jax.transfer_guard_device_to_host("disallow"):
            rn = explore_distributed(system, plan=neuron_axis(8),
                                     fault_injector=inj, **kw)
        assert {tuple(r) for r in rn.configs} == want
        assert inj.calls == 1

        # overflow regime: flags must still come back, archive sound
        hard = power_law(26, 3, seed=6)
        truth = {tuple(r) for r in explore(
            hard, max_steps=6, frontier_cap=4096, visited_cap=65536,
            max_branches=64, dedup="sort").configs}
        with jax.transfer_guard_device_to_host("disallow"):
            ro = explore_distributed(hard, max_steps=6, frontier_cap=8,
                                     visited_cap=512, max_branches=64)
        assert ro.frontier_overflow and not ro.exhausted
        assert {tuple(r) for r in ro.configs} <= truth
        print("OK", rd.num_discovered, rn.num_discovered)
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


# ---------------------------------------------------------------------------
# degree-weighted shard rebalancing
# ---------------------------------------------------------------------------


def test_degree_partition_flattens_occupancy():
    """On a heavy-tailed graph LPT packing must strictly lower the max
    per-shard degree load vs the contiguous slicing (the hubs spread
    instead of stacking into whichever slice they fell)."""
    system = power_law(48, 3, seed=3)
    occ = {}
    for part in ("contiguous", "degree"):
        comp = compile_sharded(system, neuron_axis(4, partition=part))
        occ[part] = partition_stats(comp.occupancy)
    assert occ["degree"]["max"] < occ["contiguous"]["max"]
    assert occ["degree"]["imbalance"] < occ["contiguous"]["imbalance"]
    # mean weight is partition-invariant (same neurons, same weights)
    assert occ["degree"]["mean"] == pytest.approx(
        occ["contiguous"]["mean"])


def test_degree_partition_is_deterministic():
    from repro.core import partition_neurons
    system = power_law(32, 3, seed=1)
    a = partition_neurons(system, 4, "degree")
    b = partition_neurons(system, 4, "degree")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_degree_partition_matches_single_device_4dev():
    proc = _run(4, """
        from repro.core import explore
        from repro.core.generators import power_law, random_system
        from repro.core.distributed import explore_distributed
        from repro.sharding import neuron_axis

        for system in (power_law(26, 3, seed=6),
                       random_system(9, 2, 0.3, seed=1)):
            # overflow-free caps: under frontier overflow the survivor
            # choice follows candidate enumeration order, which a
            # permuted partition legitimately changes
            kw = dict(max_steps=4, frontier_cap=512, visited_cap=2048,
                      max_branches=32)
            want = explore(system, dedup="sort", **kw)
            got = explore_distributed(
                system, plan=neuron_axis(4, partition="degree"), **kw)
            assert not (got.frontier_overflow or want.frontier_overflow)
            assert {tuple(r) for r in got.configs} == \\
                {tuple(r) for r in want.configs}, system.name
            assert got.num_discovered == want.num_discovered
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_auto_plan_picks_degree_partition_for_hub_graphs():
    """SystemPlan.for_system flips to the degree partition when the
    max in-degree dwarfs the mean (hub regime) on a multi-shard plan."""
    from repro.core import SystemPlan
    hubby = power_law(400, 3, seed=0)     # unbounded hub (heavy-tailed)
    plan = SystemPlan.for_system(hubby, num_shards=4)
    assert plan.partition == "degree"
    flat = random_system(16, 2, 0.2, seed=4)
    assert SystemPlan.for_system(flat, num_shards=4).partition \
        == "contiguous"
    assert SystemPlan.for_system(hubby).partition == "contiguous"
