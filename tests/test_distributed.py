"""Multi-device SNP exploration tests.

The main pytest process keeps the default single CPU device (the dry-run is
the only place 512 placeholder devices are allowed); these tests spawn
subprocesses with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(ndev: int, body: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env, capture_output=True, text=True, timeout=600,
    )


@pytest.mark.parametrize("ndev", [2, 8])
def test_distributed_matches_single_device(ndev):
    proc = _run(ndev, """
        import jax
        from repro.core import paper_pi, compile_system, explore
        from repro.core.distributed import explore_distributed
        from repro.core.generators import random_system

        assert len(jax.devices()) == %d

        comp = compile_system(paper_pi(True))
        rd = explore_distributed(comp, max_steps=12, frontier_cap=32,
                                 visited_cap=256, max_branches=16)
        rs = explore(comp, max_steps=12, frontier_cap=256,
                     visited_cap=2048, max_branches=16)
        assert not (rd.branch_overflow or rd.frontier_overflow
                    or rd.visited_overflow)
        assert {tuple(r) for r in rd.configs} == {tuple(r) for r in rs.configs}

        comp = compile_system(random_system(9, 2, 0.3, seed=1))
        ndev = len(jax.devices())
        rd = explore_distributed(comp, max_steps=8,
                                 frontier_cap=4096 // ndev,
                                 visited_cap=32768 // ndev, max_branches=64)
        rs = explore(comp, max_steps=8, frontier_cap=4096,
                     visited_cap=32768, max_branches=64)
        assert not (rd.frontier_overflow or rs.frontier_overflow)
        assert {tuple(r) for r in rd.configs} == {tuple(r) for r in rs.configs}
        print("OK", rd.num_discovered)
    """ % ndev)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_distributed_overflow_is_flagged_and_sound():
    proc = _run(4, """
        from repro.core import compile_system, explore
        from repro.core.distributed import explore_distributed
        from repro.core.generators import random_system

        comp = compile_system(random_system(9, 2, 0.3, seed=1))
        # tiny per-device frontier forces frontier overflow
        rd = explore_distributed(comp, max_steps=6, frontier_cap=8,
                                 visited_cap=512, max_branches=64)
        assert rd.frontier_overflow
        assert not rd.exhausted
        # soundness: everything discovered is truly reachable
        rs = explore(comp, max_steps=10, frontier_cap=8192,
                     visited_cap=65536, max_branches=64)
        truth = {tuple(r) for r in rs.configs}
        assert {tuple(r) for r in rd.configs} <= truth
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]


def test_distributed_backend_pallas_matches_ref():
    """The step backend plugs into the shard_map body: the fused Pallas
    kernel must produce the same discovered set as the jnp reference."""
    proc = _run(2, """
        from repro.core import paper_pi, compile_system
        from repro.core.distributed import explore_distributed
        comp = compile_system(paper_pi(True))
        kw = dict(max_steps=8, frontier_cap=32, visited_cap=256,
                  max_branches=16)
        rd = explore_distributed(comp, backend="ref", **kw)
        rp = explore_distributed(comp, backend="pallas", **kw)
        assert {tuple(r) for r in rd.configs} == {tuple(r) for r in rp.configs}
        print("OK", rp.num_discovered)
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_run_traces_distributed_matches_single_device_1dev():
    """In-process single-device check: the shard_map path must be
    bit-identical to run_traces on a 1-device mesh (no subprocess)."""
    import numpy as np
    from repro.core import paper_pi, run_traces
    from repro.core.distributed import run_traces_distributed

    pi = paper_pi(True)
    for policy in ("first", "random"):
        kw = dict(steps=12, seeds=[0, 1, 7, 42, 9], policy=policy,
                  max_branches=16)
        a = run_traces(pi, **kw)
        b = run_traces_distributed(pi, **kw)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("ndev", [8])
def test_run_traces_distributed_matches_single_device_multidev(ndev):
    proc = _run(ndev, """
        import jax, numpy as np
        from repro.core import paper_pi, run_traces
        from repro.core.distributed import run_traces_distributed
        from repro.core.generators import nd_chain

        assert len(jax.devices()) == %d
        for system, B, policy in [(paper_pi(True), 16, "random"),
                                  (paper_pi(True), 5, "random"),  # pad path
                                  (nd_chain(4), 8, "first")]:
            kw = dict(steps=10, seeds=list(range(B)), policy=policy,
                      max_branches=16)
            a = run_traces(system, **kw)
            b = run_traces_distributed(system, **kw)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
    """ % ndev)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_run_traces_distributed_rejects_bad_input():
    from repro.core import paper_pi
    from repro.core.distributed import run_traces_distributed

    with pytest.raises(ValueError, match="policy"):
        run_traces_distributed(paper_pi(True), steps=4, seeds=[0],
                               policy="greedy")
    with pytest.raises(ValueError, match="1-D"):
        run_traces_distributed(paper_pi(True), steps=4, seeds=[[0, 1]])


def test_distributed_drains_finite_tree():
    proc = _run(4, """
        from repro.core import compile_system
        from repro.core.distributed import explore_distributed
        from repro.core.generators import random_system
        comp = compile_system(random_system(9, 2, 0.3, seed=9))
        rd = explore_distributed(comp, max_steps=32, frontier_cap=64,
                                 visited_cap=512, max_branches=64)
        assert rd.exhausted and rd.num_discovered == 6
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
