"""Dry-run integration tests (subprocess: 512 placeholder devices).

The full 40-cell × 2-mesh sweep runs via ``python -m repro.launch.dryrun``
(results in EXPERIMENTS.md); here we gate on representative cells per step
kind + the production-mesh constructor + the SNP exploration cell, so CI
catches sharding regressions quickly.
"""

import json
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)   # dryrun.py sets its own
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO)


def test_production_mesh_shapes():
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=512"
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            assert m1.devices.shape == (16, 16)
            assert m1.axis_names == ("data", "model")
            m2 = make_production_mesh(multi_pod=True)
            assert m2.devices.shape == (2, 16, 16)
            assert m2.axis_names == ("pod", "data", "model")
            print("OK")
        """)],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr[-2000:]


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),        # train lowering
    ("minicpm3-4b", "decode_32k"),      # MLA decode w/ latent cache
    ("rwkv6-7b", "long_500k"),          # attention-free long-context decode
])
def test_single_cell_both_meshes(arch, shape, tmp_path):
    proc = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "both",
                        "--out", str(tmp_path)])
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    for mesh in ("16x16", "2x16x16"):
        rec = json.load(open(tmp_path / f"{arch}__{shape}__{mesh}.json"))
        assert rec["compute_s"] > 0
        assert rec["bound"] in ("compute", "memory", "collective")
        # multi-pod proves the pod axis shards: 512 chips
    assert json.load(
        open(tmp_path / f"{arch}__{shape}__2x16x16.json"))["chips"] == 512


def test_long500k_skipped_for_full_attention(tmp_path):
    proc = _run_dryrun(["--arch", "smollm-360m", "--shape", "long_500k",
                        "--mesh", "single", "--out", str(tmp_path)])
    assert proc.returncode == 0
    assert "SKIP" in proc.stdout


def test_snp_exploration_cell(tmp_path):
    proc = _run_dryrun(["--arch", "smollm-360m", "--shape", "train_4k",
                        "--mesh", "single", "--snp", "--out",
                        str(tmp_path)])
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    snp = [f for f in os.listdir(tmp_path) if f.startswith("snp-")]
    assert snp, os.listdir(tmp_path)
    rec = json.load(open(tmp_path / snp[0]))
    # the exchange must actually use all_to_all on the wire
    assert rec["collective_counts"]["all-to-all"] >= 1
