"""Tier-1 enforcement of the docs layer: every internal link in the repo's
markdown set must resolve (same checker CI runs — ``tools/check_links.py``)."""

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _checker():
    spec = importlib.util.spec_from_file_location(
        "check_links", REPO / "tools" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_readme_exists_with_required_sections():
    readme = REPO / "README.md"
    assert readme.exists(), "top-level README.md is required"
    text = readme.read_text()
    for needed in ("Quickstart", "backend", "DESIGN.md", "EXPERIMENTS.md"):
        assert needed in text, f"README.md lacks {needed!r}"


def test_all_doc_links_resolve():
    mod = _checker()
    errors = []
    for name in mod.DEFAULT_DOCS:
        path = REPO / name
        if path.exists():
            errors += mod.check_file(path)
    assert not errors, "\n".join(str(e) for e in errors)


def test_checker_flags_broken_links(tmp_path):
    mod = _checker()
    md = tmp_path / "doc.md"
    md.write_text("# Title\n[ok](doc.md) [bad](missing.md) "
                  "[ok2](#title) [bad2](#nope)\n")
    errors = mod.check_file(md)
    assert len(errors) == 2
    assert any("missing.md" in e for e in errors)
    assert any("nope" in e for e in errors)


def test_checker_handles_caret_text_and_titled_links(tmp_path):
    """Regression: link text with '^' and targets with a "title" part must
    still be parsed (an earlier regex silently skipped both)."""
    mod = _checker()
    md = tmp_path / "doc.md"
    md.write_text('# Title\n[O(n^2) path](gone.md) '
                  '[titled](also-gone.md "a title")\n')
    errors = mod.check_file(md)
    assert len(errors) == 2


def test_checker_ignores_fenced_code_and_suffixes_duplicate_headings(
        tmp_path):
    mod = _checker()
    md = tmp_path / "doc.md"
    md.write_text("# Part\ntext\n```bash\n# not a heading\n"
                  "[not a link](gone.md)\n```\n# Part\n"
                  "[dup ok](#part-1) [phantom](#not-a-heading)\n")
    errors = mod.check_file(md)
    assert len(errors) == 1            # fenced 'link' skipped, dup-1 valid
    assert "not-a-heading" in errors[0]


def test_github_slugging():
    mod = _checker()
    assert mod.github_slug("§4 Serving architecture") == \
        "4-serving-architecture"
    assert mod.github_slug("Paper → module map") == "paper--module-map"
