"""Sparse encoding + sparse step tests.

Covers the compile layer (vectorized dense M vs. brute force, ELL/segment
encoding round-trips, compile-time regression), the sparse step semantics
(bit-identity with the dense oracle, including the edge cases a sparse
path can get wrong: rules with zero synapses out, neurons with no rules,
Ψ-overflow parity, n-d batches), and the fused sparse Pallas kernel's
block sweep."""

import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import assert_same_step as _assert_same_step
from repro.core import (compile_system, compile_system_sparse, explore,
                        get_backend, paper_pi, successor_set)
from repro.core.generators import (counter, nd_chain, power_law,
                                   random_system, ring, ring_lattice, torus)
from repro.core.semantics import next_configs, sparse_next_configs
from repro.core.system import Rule, SNPSystem
from repro.kernels.snp_step import snp_step_sparse

SYSTEMS = {
    "paper-pi": (paper_pi(True), 16),
    "paper-pi-exact": (paper_pi(False), 16),
    "ring-9": (ring(9), 8),
    "counter-4": (counter(4), 8),
    "nd-chain-6": (nd_chain(6), 64),
    "random-17": (random_system(17, 3, 0.3, seed=3), 32),
    "ring-lattice-12": (ring_lattice(12, 3, seed=1), 16),
    "torus-4x5": (torus(4, 5, seed=2), 16),
    "power-law-20": (power_law(20, 3, seed=3), 16),
}


def _brute_force_M(system):
    """The seed's original O(n·m) synapse-set scan, kept as the oracle for
    the vectorized adjacency construction."""
    n, m = system.num_rules, system.num_neurons
    order = sorted(range(n), key=lambda i: system.rules[i].neuron)
    rules = [system.rules[i] for i in order]
    syn = set(system.synapses)
    M = np.zeros((n, m), dtype=np.int32)
    for i, r in enumerate(rules):
        M[i, r.neuron] = -r.consume
        if r.produce > 0:
            for j in range(m):
                if (r.neuron, j) in syn:
                    M[i, j] = r.produce
    return M, tuple(order)


# _assert_same_step lives in conftest.py (shared by the equivalence
# suites); imported above under its historical local name.


# ---------------------------------------------------------------------------
# compile layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_vectorized_dense_compile_matches_brute_force(name):
    system, _ = SYSTEMS[name]
    comp = compile_system(system)
    M, order = _brute_force_M(system)
    assert comp.rule_order == order
    np.testing.assert_array_equal(np.asarray(comp.M), M)


@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_sparse_encoding_round_trips(name):
    system, _ = SYSTEMS[name]
    sp = compile_system_sparse(system)
    M, order = _brute_force_M(system)
    n, m = system.num_rules, system.num_neurons
    assert sp.rule_order == order

    # ELL rows scatter back to exactly the dense M (pad column m stays 0)
    Mr = np.zeros((n, m + 1), np.int32)
    ec, ev = np.asarray(sp.ell_col), np.asarray(sp.ell_val)
    np.add.at(Mr, (np.repeat(np.arange(n), ec.shape[1]), ec.ravel()),
              ev.ravel())
    np.testing.assert_array_equal(Mr[:, :m], M)
    assert not Mr[:, m].any()
    # measured ELL width is tight and nnz counts are exact
    np.testing.assert_array_equal(np.asarray(sp.ell_nnz),
                                  (M != 0).sum(axis=1))
    assert sp.max_nnz_per_rule == max(1, int((M != 0).sum(axis=1).max()))

    # per-neuron segments partition the neuron-sorted rule axis
    ss, sc = np.asarray(sp.seg_start), np.asarray(sp.seg_count)
    rn = np.asarray(sp.rule_neuron)
    assert sc.sum() == n
    for mu in range(m):
        assert (rn[ss[mu]:ss[mu] + sc[mu]] == mu).all()

    # ELL in-adjacency == transposed synapse graph
    ii = np.asarray(sp.in_idx)
    for j in range(m):
        got = sorted(int(x) for x in ii[j] if x < m)
        assert got == sorted(i for (i, jj) in system.synapses if jj == j)


def test_sparse_compile_never_builds_dense_arrays():
    sp = compile_system_sparse(ring_lattice(512, 4, seed=0))
    n, m = sp.num_rules, sp.num_neurons
    for arr in sp[:-1]:
        if hasattr(arr, "size"):
            assert arr.size < n * m / 4, "O(n·m)-sized field in sparse comp"


def test_compile_time_regression_vectorized_adjacency():
    """The seed's per-rule × per-neuron Python loop took O(n·m) set lookups
    (~tens of seconds here); the vectorized adjacency indexing must stay
    orders of magnitude below that.  Generous bound for slow CI workers."""
    system = ring_lattice(4096, 8, seed=0)
    t0 = time.perf_counter()
    compile_system(system)
    dense_t = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = compile_system_sparse(system)
    sparse_t = time.perf_counter() - t0
    assert dense_t < 8.0, f"dense compile too slow: {dense_t:.1f}s"
    assert sparse_t < 8.0, f"sparse compile too slow: {sparse_t:.1f}s"
    assert sp.max_in_degree == 8 and sp.max_nnz_per_rule == 9


def test_sparse_compile_rejects_unpackable_rules():
    big = SNPSystem(
        2, (1, 0),
        (Rule(neuron=0, consume=40000, produce=1, regex_base=40000,
              covering=True),),
        ((0, 1),), output_neuron=1)
    with pytest.raises(ValueError, match="2\\^15"):
        compile_system_sparse(big)


# ---------------------------------------------------------------------------
# sparse step semantics: bit-identity with the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_sparse_step_matches_dense_oracle(name):
    system, T = SYSTEMS[name]
    dn, sp = compile_system(system), compile_system_sparse(system)
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    cfgs = jnp.asarray(rng.integers(0, 5, size=(6, dn.num_neurons)),
                       jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, T),
                      sparse_next_configs(cfgs, sp, T))


def test_rule_heavy_neurons_use_gather_fallback():
    """R > 8 rules per neuron flips _fired_packed to the take_along_axis
    fallback; it must stay bit-identical too."""
    system = random_system(6, 9, 0.4, max_spikes=5, seed=8)
    dn, sp = compile_system(system), compile_system_sparse(system)
    assert sp.max_rules_per_neuron > 8
    rng = np.random.default_rng(8)
    cfgs = jnp.asarray(rng.integers(0, 6, size=(5, 6)), jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, 32),
                      sparse_next_configs(cfgs, sp, 32))


def test_rule_with_zero_synapses_out():
    """A produce rule whose neuron has no outgoing synapses: its M row is
    only the consume entry (spikes go nowhere, not even the environment
    unless it's the output neuron)."""
    system = SNPSystem(
        3, (2, 1, 1),
        (Rule(neuron=0, consume=1, produce=1, regex_base=1, covering=True),
         Rule(neuron=1, consume=1, produce=1, regex_base=1, covering=True),
         Rule(neuron=2, consume=1, produce=2, regex_base=1, covering=True)),
        ((0, 1),),                      # neurons 1 and 2 have no out-synapses
        output_neuron=2)
    dn, sp = compile_system(system), compile_system_sparse(system)
    cfgs = jnp.asarray([[2, 1, 1], [0, 3, 2], [1, 0, 0]], jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, 8),
                      sparse_next_configs(cfgs, sp, 8))
    # and the emission still happens: neuron 2's rule feeds the environment
    out = sparse_next_configs(jnp.asarray([0, 0, 1], jnp.int32), sp, 4)
    assert int(np.asarray(out.emissions)[np.asarray(out.valid)][0]) == 2


def test_neuron_with_no_rules():
    system = SNPSystem(
        4, (1, 1, 0, 1),
        (Rule(neuron=0, consume=1, produce=1, regex_base=1, covering=True),
         Rule(neuron=3, consume=1, produce=1, regex_base=1, covering=True)),
        ((0, 1), (0, 2), (3, 2)),       # neurons 1, 2 own no rules
        output_neuron=3)
    dn, sp = compile_system(system), compile_system_sparse(system)
    assert int(np.asarray(sp.seg_count)[1]) == 0
    assert int(np.asarray(sp.seg_count)[2]) == 0
    cfgs = jnp.asarray([[1, 1, 0, 1], [0, 5, 5, 0], [2, 0, 0, 2]], jnp.int32)
    _assert_same_step(next_configs(cfgs, dn, 8),
                      sparse_next_configs(cfgs, sp, 8))


def test_overflow_flag_parity_with_ref():
    """Ψ = 2^8 = 256 > T = 16: both paths must flag overflow and agree on
    the first T branches (the deterministic valid subset)."""
    system = nd_chain(8)
    dn, sp = compile_system(system), compile_system_sparse(system)
    c0 = jnp.asarray([system.initial_spikes], jnp.int32)
    a = next_configs(c0, dn, 16)
    b = sparse_next_configs(c0, sp, 16)
    assert bool(np.asarray(a.overflow)[0]) and bool(np.asarray(b.overflow)[0])
    _assert_same_step(a, b)


@pytest.mark.parametrize("backend", ["sparse", "sparse_pallas"])
def test_supports_nd_batch_round_trip(backend):
    system, T = SYSTEMS["random-17"]
    be = get_backend(backend)
    assert be.supports_nd_batch
    comp = be.compile(system)
    rng = np.random.default_rng(7)
    flat = jnp.asarray(rng.integers(0, 4, size=(6, 17)), jnp.int32)
    nd = flat.reshape(2, 3, 17)
    a = be.expand(flat, comp, T)
    b = be.expand(nd, comp, T)
    assert b.configs.shape == (2, 3, T, 17)
    assert b.valid.shape == (2, 3, T)
    assert b.overflow.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(a.configs),
                                  np.asarray(b.configs).reshape(6, T, 17))
    np.testing.assert_array_equal(np.asarray(a.valid),
                                  np.asarray(b.valid).reshape(6, T))
    np.testing.assert_array_equal(np.asarray(a.overflow),
                                  np.asarray(b.overflow).reshape(6))


# ---------------------------------------------------------------------------
# fused sparse kernel: block sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_b,block_t", [(1, 4), (2, 16), (4, 8), (8, 32)])
def test_sparse_kernel_block_sweep(block_b, block_t):
    system, T = SYSTEMS["random-17"]
    dn, sp = compile_system(system), compile_system_sparse(system)
    rng = np.random.default_rng(0)
    cfgs = jnp.asarray(rng.integers(0, 4, size=(7, 17)), jnp.int32)
    o, v, e, f = snp_step_sparse(cfgs, sp, max_branches=T,
                                 block_b=block_b, block_t=block_t)
    ref = next_configs(cfgs, dn, T)
    va = np.asarray(ref.valid)
    np.testing.assert_array_equal(va, np.asarray(v))
    np.testing.assert_array_equal(np.asarray(ref.overflow), np.asarray(f))
    np.testing.assert_array_equal(
        np.where(va[..., None], np.asarray(ref.configs), 0),
        np.where(va[..., None], np.asarray(o), 0))
    np.testing.assert_array_equal(
        np.where(va, np.asarray(ref.emissions), 0),
        np.where(va, np.asarray(e), 0))


# ---------------------------------------------------------------------------
# consumers on the sparse path
# ---------------------------------------------------------------------------

def test_successor_set_sparse_matches_ref():
    pi = paper_pi(True)
    assert successor_set(pi, (2, 1, 1), 16, "sparse") \
        == successor_set(pi, (2, 1, 1), 16, "ref")
    # pre-compiled sparse encodings pass straight through
    sp = compile_system_sparse(pi)
    assert successor_set(sp, (2, 1, 1), 16, "sparse") \
        == successor_set(pi, (2, 1, 1), 16, "ref")


def test_explore_sparse_on_seeded_random_systems():
    for seed in (0, 1):
        system = random_system(12, 2, 0.3, seed=seed)
        kw = dict(max_steps=5, frontier_cap=128, visited_cap=1024,
                  max_branches=32)
        ref = explore(system, backend="ref", **kw)
        got = explore(system, backend="sparse", **kw)
        np.testing.assert_array_equal(ref.configs, got.configs)
        assert ref.exhausted == got.exhausted
