"""Serving-path and sharding-plan unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.models import init_cache, init_params, forward
from repro.serve import make_decode_step, make_prefill_step, sample_token
from repro.sharding import make_plan


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("smollm-360m"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def test_prefill_then_greedy_decode_is_deterministic(tiny):
    cfg, params = tiny
    B, S, G = 2, 16, 5
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(seed=1), step=0, shard=0, batch=B,
        seq_len=S).items() if k != "labels"}
    prefill = jax.jit(make_prefill_step(cfg, max_len=S + G + 1))
    decode = jax.jit(make_decode_step(cfg))

    def run():
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        toks = []
        for g in range(G):
            pos = jnp.full((B, 1), S + g, jnp.int32)
            tok, _, cache2 = decode(params, cache, tok, pos,
                                    jax.random.PRNGKey(0))
            cache = cache2
            toks.append(np.asarray(tok))
        return np.concatenate(toks, -1)

    a, b = run(), run()
    np.testing.assert_array_equal(a, b)


def test_prefill_last_logits_match_forward(tiny):
    cfg, params = tiny
    B, S = 2, 12
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(seed=2), step=0, shard=0, batch=B,
        seq_len=S).items() if k != "labels"}
    prefill = make_prefill_step(cfg, max_len=S + 2)
    logits, _ = prefill(params, batch)
    full, _, _ = forward(params, cfg, batch, mode="train", remat="none")
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_sample_token_temperature():
    logits = jnp.asarray([[0.0, 10.0, 0.0]])
    assert int(sample_token(logits, jax.random.PRNGKey(0), 0.0)[0]) == 1
    # high temperature: samples vary across keys
    toks = {int(sample_token(logits * 0.01, jax.random.PRNGKey(k), 5.0)[0])
            for k in range(32)}
    assert len(toks) > 1


def test_activation_stationary_decode_matches_default(tiny):
    """The decode sharding remap must not change values (single device:
    constraints are no-ops, but the kind-remap path still executes)."""
    cfg, params = tiny
    B, S = 1, 8
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(seed=3), step=0, shard=0, batch=B,
        seq_len=S).items() if k != "labels"}
    prefill = make_prefill_step(cfg, max_len=S + 2)
    _, cache = prefill(params, batch)
    tok = jnp.asarray([[5]], jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    key = jax.random.PRNGKey(0)
    d1 = make_decode_step(cfg, activation_stationary=True)
    d2 = make_decode_step(cfg, activation_stationary=False)
    t1, l1, _ = d1(params, cache, tok, pos, key)
    t2, l2, _ = d2(params, cache, tok, pos, key)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


# --------------------------------------------------------------------------
# sharding plan
# --------------------------------------------------------------------------

def _fake_mesh(shape=(2, 2), names=("data", "model")):
    # abstract mesh: AbstractMesh supports .shape lookups for plan logic
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        # jax <= 0.4.x takes a single ((name, size), ...) shape tuple
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def test_fit_drops_non_divisible_axes():
    plan = make_plan(_fake_mesh((2, 2)))
    # dim 5 cannot shard over 2 -> axis dropped
    assert plan.fit(P("model", None), (5, 8)) == P(None, None)
    assert plan.fit(P("model", None), (4, 8)) == P("model", None)


def test_fit_sheds_outer_axes_of_tuples_first():
    plan = make_plan(_fake_mesh((2, 4, 2), ("pod", "data", "model")))
    assert plan.fsdp == ("pod", "data")
    # 8 % (2*4) == 0: keep both; 4 % 8 != 0 -> shed 'pod', keep 'data'
    assert plan.fit(P(("pod", "data")), (8,)) == P(("pod", "data"))
    assert plan.fit(P(("pod", "data")), (4,)) == P("data")
    assert plan.fit(P(("pod", "data")), (3,)) == P(None)


def test_param_specs_cover_all_leaves():
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    params = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    plan = make_plan(_fake_mesh((2, 2)))
    specs = plan.param_specs(cfg, params)
    leaves_p = jax.tree.leaves(params)
    leaves_s = jax.tree.leaves(specs,
                               is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for p, s in zip(leaves_p, leaves_s):
        assert len(s) <= p.ndim
        # every spec must divide its dims
        for dim, entry in zip(p.shape, tuple(s) + (None,) * p.ndim):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= {"data": 2, "model": 2}[a]
            assert dim % size == 0, (p.shape, s)


def test_cache_specs_shard_kv_sequence():
    cfg = reduced(get_config("command-r-35b"))
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 64))
    plan = make_plan(_fake_mesh((2, 2)))
    specs = plan.cache_specs(cfg, cache)
    k_spec = specs["pos0"]["k"]
    assert k_spec[2] == "model"   # sequence dim sharded over model
    assert k_spec[1] == "data"    # batch over data


def test_batch_specs_musicgen_codebooks():
    cfg = reduced(get_config("musicgen-medium"))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 4, 16), jnp.int32),
             "positions": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
    plan = make_plan(_fake_mesh((2, 2)))
    specs = plan.batch_specs(cfg, batch)
    assert specs["tokens"] == P("data", None, None)


def test_trace_mesh_flattens_all_devices():
    """SNP trace serving treats the whole mesh as one data axis: the plan's
    trace mesh must be 1-D over every device (concrete mesh required)."""
    devs = np.array(jax.devices())
    plan = make_plan(Mesh(devs.reshape(-1, 1), ("data", "model")))
    tm = plan.trace_mesh()
    assert tm.axis_names == ("traces",)
    assert tm.devices.size == devs.size
