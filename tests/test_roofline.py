"""Roofline analysis unit tests: HLO collective parsing, term arithmetic,
and an end-to-end mini dry-run cross-check against analytic FLOPs."""

import numpy as np
import pytest

from repro.roofline.analysis import (HW, parse_collectives, roofline_terms)

HLO_SAMPLE = """
HloModule jit_step

ENTRY %main {
  %p0 = f32[256,1024]{1,0} parameter(0)
  %ag = f32[4096,1024]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[256,1024]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[16,1024]{1,0} reduce-scatter(%p0), replica_groups=[1,256]<=[256], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = f32[64,32]{1,0} all-to-all(%p0), replica_groups=[32,8]<=[256]
  %ars = f32[256,1024]{1,0} all-reduce-start(%p0), replica_groups={{0,1}}
  %ard = f32[256,1024]{1,0} all-reduce-done(%ars)
  %dot = f32[256,256]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}
}
"""


def test_parse_collectives_counts_and_groups():
    stats = parse_collectives(HLO_SAMPLE, default_group=256)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["all-reduce"] == 2      # incl. -start, not -done
    assert stats.counts["reduce-scatter"] == 1
    assert stats.counts["collective-permute"] == 1
    assert stats.counts["all-to-all"] == 1
    # all-gather output 4096*1024*4 bytes with group 16
    ag_bytes = 4096 * 1024 * 4
    assert stats.tensor_bytes["all-gather"] == ag_bytes
    # wire bytes: ring factors
    ar_bytes = 256 * 1024 * 4
    rs_bytes = 16 * 1024 * 4
    cp_bytes = 8 * 128 * 2
    a2a_bytes = 64 * 32 * 4
    expected = (ag_bytes * 15 / 16
                + 2 * ar_bytes * 3 / 4
                + rs_bytes * 255 / 256
                + cp_bytes
                + a2a_bytes * 7 / 8
                + 2 * ar_bytes * 1 / 2)
    assert abs(stats.link_bytes - expected) / expected < 1e-6


def test_parse_collectives_ignores_non_collectives():
    stats = parse_collectives(
        "%d = f32[10,10] dot(%a, %b)\n%c = f32[2] constant({1,2})", 8)
    assert stats.total_count() == 0
    assert stats.link_bytes == 0


def test_roofline_terms_bound_selection():
    t = roofline_terms(flops=197e12, hbm_bytes=0, link_bytes=0, chips=1)
    assert abs(t["compute_s"] - 1.0) < 1e-9 and t["bound"] == "compute"
    t = roofline_terms(flops=0, hbm_bytes=819e9, link_bytes=0, chips=1)
    assert abs(t["memory_s"] - 1.0) < 1e-9 and t["bound"] == "memory"
    t = roofline_terms(flops=0, hbm_bytes=0, link_bytes=50e9, chips=1)
    assert abs(t["collective_s"] - 1.0) < 1e-9 and t["bound"] == "collective"


def test_roofline_useful_flops_ratio():
    t = roofline_terms(flops=2e12, hbm_bytes=0, link_bytes=0, chips=4,
                       model_flops=6e12)
    assert abs(t["useful_flops_frac"] - (6e12 / 4) / 2e12) < 1e-9


def test_hlo_analyzer_plain_matmul():
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_analyzer import analyze_hlo

    M = 256
    txt = jax.jit(lambda x, w: x @ w).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile().as_text()
    c = analyze_hlo(txt)
    assert abs(c.flops - 2 * M ** 3) / (2 * M ** 3) < 0.01
    assert c.num_whiles == 0


def test_hlo_analyzer_counts_scan_trip_counts():
    """XLA cost_analysis counts while bodies once; the loop-aware analyzer
    must multiply by known_trip_count — including nested scans and
    remat-recomputed bodies."""
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_analyzer import analyze_hlo

    M = 128
    one = 2 * M ** 3
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def f9(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=9)[0]

    txt = jax.jit(f9).lower(a, a).compile().as_text()
    c = analyze_hlo(txt)
    assert abs(c.flops - 9 * one) / (9 * one) < 0.01
    assert c.max_trip_count == 9

    def nested(x, w):
        def inner(c, _):
            return jax.lax.scan(lambda d, _: (d @ w, None), c, None,
                                length=5)[0], None
        return jax.lax.scan(inner, x @ w, None, length=4)[0]

    txt = jax.jit(nested).lower(a, a).compile().as_text()
    assert abs(analyze_hlo(txt).flops - 21 * one) / (21 * one) < 0.01

    def loss(x, w):
        body = jax.checkpoint(lambda c, _: (jnp.tanh(c @ w), None))
        out, _ = jax.lax.scan(body, x, None, length=8)
        return (out ** 2).sum()

    txt = jax.jit(jax.grad(loss, argnums=1)).lower(a, a).compile().as_text()
    flops = analyze_hlo(txt).flops
    # 8 x (fwd + remat recompute + 2 bwd dots) = 32 matmuls
    assert abs(flops - 32 * one) / (32 * one) < 0.05


def test_cost_analysis_matches_analytic_flops_single_device():
    """End-to-end calibration: XLA cost_analysis FLOPs for a pure matmul
    chain must match the analytic count (this validates using
    cost_analysis for the roofline compute term)."""
    import jax
    import jax.numpy as jnp

    def f(a, b):
        return a @ b

    M = K = N = 256
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0))
    assert abs(flops - 2 * M * K * N) / (2 * M * K * N) < 0.05
