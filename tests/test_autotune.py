"""Planner & autotuner tests (DESIGN.md §3 "Planner & autotuner").

Registry-driven coverage of the three tentpole pieces:

* every ``mode="auto"`` decision — whatever backend/encoding/block shape
  the planner picks — stays bit-identical to the ``"ref"`` oracle across
  the backend × encoding matrix (forced through poked cache entries);
* the autotune cache round-trips to disk keyed on the full
  ``(m, n, K_in, B, T)`` workload signature;
* a poisoned/corrupt cache file degrades to the analytic model with a
  ``UserWarning`` instead of crashing;
* ``KernelConfig`` validation: lower-time applicability errors, and the
  cache-collision audit (two block configurations resolve to *distinct*
  backend instances, so every backend-keyed executable cache — jit
  static args, ``_traces_shard_fn`` — keys on the block shape).
"""

import dataclasses
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (KernelConfig, SystemPlan, available_backends,
                        explore, get_backend, resolve_kernel, run_traces)
from repro.core.autotune import (TunedChoice, WorkloadSignature, load_cache,
                                 lookup, model_choice, plan_for, predict_us,
                                 signature_of, store_choice)
from repro.core.generators import ring_lattice

SEEDS = [0, 1, 2]
STEPS = 6
T = 8


@pytest.fixture()
def cache_file(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    return path


def _system():
    return ring_lattice(12, 3, seed=0)


def _force_choice(system, choice):
    """Poke ``choice`` into the cache at the exact signature
    ``run_traces(seeds=SEEDS, max_branches=T)`` plans for."""
    sig = signature_of(system, workload=(len(SEEDS), T))
    store_choice(sig, choice)
    return sig


def _single_device_encodings(name):
    return [e for e in get_backend(name).supported_encodings()
            if e != "sharded"]


@pytest.mark.parametrize("name", sorted(available_backends()))
def test_auto_decisions_bit_identical_to_ref(name, cache_file):
    """Force the planner onto every (backend, encoding) cell and check
    run_traces under the default auto plan matches the ref oracle."""
    system = _system()
    ref = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T,
                     backend="ref")
    blocks = {"block_b": 2, "block_t": 4} if \
        hasattr(get_backend(name), "block_b") else {}
    for encoding in _single_device_encodings(name):
        _force_choice(system, TunedChoice(backend=name, encoding=encoding,
                                          **blocks))
        plan = SystemPlan.for_system(system, workload=(len(SEEDS), T),
                                     mode="auto")
        assert plan.backend == name and plan.encoding == encoding
        got = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_auto_explore_matches_ref_archive(cache_file):
    """End-to-end: the default backend=None explore (planner-decided)
    discovers exactly the ref archive."""
    system = _system()
    ref = explore(system, max_steps=8, frontier_cap=32, visited_cap=256,
                  max_branches=T, backend="ref")
    auto = explore(system, max_steps=8, frontier_cap=32, visited_cap=256,
                   max_branches=T)
    assert sorted(ref.as_strings()) == sorted(auto.as_strings())


def test_cache_round_trips_on_full_signature(cache_file):
    sig = WorkloadSignature(m=7, n=13, kin=3, B=4, T=8)
    choice = TunedChoice(backend="sparse", encoding="ell", block_b=2,
                         block_t=4, us_per_step=12.5, source="measure")
    store_choice(sig, choice)

    got = lookup(sig)
    assert got is not None
    assert (got.backend, got.encoding, got.block_b, got.block_t) == \
        ("sparse", "ell", 2, 4)

    # the key carries every signature field: perturbing any one misses
    for field in ("m", "n", "kin", "B", "T"):
        other = dataclasses.replace(sig, **{field: getattr(sig, field) + 1})
        assert lookup(other) is None, field

    payload = json.loads(cache_file.read_text())
    assert "m7_n13_kin3_B4_T8" in payload["entries"]
    assert load_cache(cache_file) == payload["entries"]


def test_corrupt_cache_degrades_to_model_with_warning(cache_file):
    cache_file.write_text("{this is not json")
    with pytest.warns(UserWarning, match="autotune cache"):
        plan = SystemPlan.for_system(_system(), workload=(4, 8),
                                     mode="auto")
    # still a usable plan (model or heuristic decided), and still correct
    assert isinstance(plan, SystemPlan)
    system = _system()
    ref = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T,
                     backend="ref")
    with pytest.warns(UserWarning, match="autotune cache"):
        got = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_poisoned_entry_is_skipped_not_fatal(cache_file):
    """Valid JSON, nonsense content: the entry is ignored, planning
    proceeds (model/heuristic), nothing raises."""
    system = _system()
    sig = signature_of(system, workload=(len(SEEDS), T))
    cache_file.write_text(json.dumps({"version": 1, "entries": {
        sig.key(): {"backend": "no-such-backend", "block_b": "huge"},
    }}))
    assert lookup(sig) is None
    plan = SystemPlan.for_system(system, workload=(len(SEEDS), T),
                                 mode="auto")
    assert isinstance(plan, SystemPlan)


def test_measure_mode_times_and_persists(cache_file):
    system = _system()
    plan = SystemPlan.for_system(system, workload=(4, T), mode="measure")
    assert plan.backend in available_backends()
    sig = signature_of(system, workload=(4, T))
    entries = load_cache(cache_file)
    assert sig.key() in entries
    assert entries[sig.key()]["source"] == "measure"
    assert entries[sig.key()]["us_per_step"] > 0
    # and the measured winner is found by a subsequent auto plan
    again = SystemPlan.for_system(system, workload=(4, T), mode="auto")
    assert again.backend == plan.backend


def test_model_predicts_and_guards_extrapolation(cache_file):
    small = WorkloadSignature(m=16, n=32, kin=3, B=8, T=8)
    assert predict_us(small, "ref") > 0
    choice = model_choice(small)
    assert choice is not None and choice.source == "model"
    # interpret-mode kernels are never picked far outside their fitted
    # support: at bench-exceeding work sizes the model must choose one of
    # the non-interpret backends, which the baseline says win there anyway
    huge = WorkloadSignature(m=10 ** 5, n=2 * 10 ** 5, kin=8,
                             B=256, T=64)
    assert model_choice(huge).backend in ("ref", "sparse")


def test_workload_hint_reaches_the_signature():
    system = _system()
    sig = signature_of(system, workload=(17, 5))
    assert (sig.B, sig.T) == (17, 5)
    assert (sig.m, sig.n) == (system.num_neurons, system.num_rules)
    in_deg_max = sig.kin
    assert in_deg_max >= 1


def test_kernel_config_validation():
    with pytest.raises(ValueError, match="block_b"):
        KernelConfig(block_b=0)
    with pytest.raises(ValueError, match="block_t"):
        KernelConfig(block_t=-4)
    cfg = KernelConfig(block_b=4).merged(block_t=8)
    assert (cfg.block_b, cfg.block_t, cfg.block_n) == (4, 8, None)
    assert hash(KernelConfig(block_b=4)) == hash(KernelConfig(block_b=4))


def test_resolve_kernel_applicability_errors():
    cfg = KernelConfig(block_b=4, block_t=8)
    for name in ("ref", "sparse"):
        with pytest.raises(ValueError, match="no kernel block"):
            resolve_kernel(get_backend(name), SystemPlan(kernel=cfg))
    with pytest.raises(ValueError, match="block_n"):
        resolve_kernel(get_backend("sparse_pallas"),
                       SystemPlan(kernel=KernelConfig(block_n=128)))
    # and the same errors surface at lower/compile time
    with pytest.raises(ValueError, match="no kernel block"):
        get_backend("ref").compile(_system(), plan=SystemPlan(kernel=cfg))


def test_resolve_kernel_reblocks_and_keys_caches():
    base = get_backend("sparse_pallas")
    be1 = resolve_kernel(base, SystemPlan(
        kernel=KernelConfig(block_b=2, block_t=4)))
    be2 = resolve_kernel(base, SystemPlan(
        kernel=KernelConfig(block_b=4, block_t=8)))
    assert (be1.block_b, be1.block_t) == (2, 4)
    assert be1 != be2 and hash(be1) != hash(be2)
    # None axes keep the backend's own defaults
    be3 = resolve_kernel(base, SystemPlan(kernel=KernelConfig(block_b=2)))
    assert (be3.block_b, be3.block_t) == (2, base.block_t)
    # the lru-cached distributed shard_map keys on the instance: distinct
    # block configs -> distinct executables, equal config -> cache hit
    from repro.core.distributed import _flat_mesh, _traces_shard_fn
    mesh, axis = _flat_mesh(None)
    f1 = _traces_shard_fn(mesh, axis, 4, 8, "first", be1)
    f2 = _traces_shard_fn(mesh, axis, 4, 8, "first", be2)
    f1b = _traces_shard_fn(mesh, axis, 4, 8, "first", resolve_kernel(
        base, SystemPlan(kernel=KernelConfig(block_b=2, block_t=4))))
    assert f1 is not f2
    assert f1 is f1b


def test_plan_kernel_runs_bit_identical_with_odd_blocks(cache_file):
    """A plan-carried kernel config with awkward block shapes exercises
    the padding path and still matches ref bit-for-bit."""
    system = _system()
    ref = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T,
                     backend="ref")
    for name, cfg in [("pallas", KernelConfig(block_b=3, block_t=5)),
                      ("sparse_pallas", KernelConfig(block_b=3, block_t=5))]:
        got = run_traces(system, steps=STEPS, seeds=SEEDS, max_branches=T,
                         backend=name, plan=SystemPlan(kernel=cfg))
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_static_mode_keeps_the_heuristic(cache_file):
    """mode="static" never consults cache or model (a poisoned cache file
    must not even be read)."""
    cache_file.write_text("{broken")
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = SystemPlan.for_system(_system())
    assert plan.backend is None and plan.encoding in ("ell", "hybrid")


def test_sharded_planning_picks_sharded_capable_backend(cache_file):
    system = _system()
    plan = plan_for(system, num_shards=2, workload=(8, T))
    if plan is not None:
        assert plan.encoding == "ell" and plan.num_shards == 2
        assert "sharded" in \
            get_backend(plan.backend).supported_encodings()
