"""Kernel-lowering layer tests (DESIGN.md §3 "Kernel lowering"): the
backend × encoding × sharded/unsharded matrix, registry-driven.

Every registered backend declares its realizable plan encodings
(``StepBackend.supported_encodings``); this module walks that declaration
and asserts bit-identity to ``"ref"`` for every cell — including the
interpret-mode Pallas kernels, hub-tail-only hybrid encodings
(``hub_threshold=1``: every hub in-synapse rides the COO stage), and the
neuron-axis-sharded paths on a faked 8-device mesh (subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count``, same convention as
``tests/test_sharded_frontier.py``), where empty shards (m=3 over 8
devices) must also hold."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

import conftest
from repro.core import (SystemPlan, available_backends, compile_sharded,
                        get_backend, paper_pi, supports_sharded)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SYSTEM_NAMES = ("paper-pi", "random-17", "ring-lattice-12", "power-law-40")


def _run(ndev: int, body: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env, capture_output=True, text=True, timeout=600,
    )


# ---------------------------------------------------------------------------
# the registry declaration itself
# ---------------------------------------------------------------------------

def test_lowering_registry_declarations():
    """Every backend declares a non-empty encoding tuple whose first
    entry is its native layout, built-ins all support 'sharded', and the
    declared single-device encodings are exactly the compilable ones."""
    for name in available_backends():
        be = get_backend(name)
        sup = be.supported_encodings()
        assert sup and sup[0] in ("dense", "ell")
        assert supports_sharded(be)
    assert get_backend("ref").supported_encodings()[0] == "dense"
    assert get_backend("sparse").supported_encodings()[0] == "ell"
    assert "hybrid" in get_backend("sparse_pallas").supported_encodings()
    assert "hybrid" not in get_backend("pallas").supported_encodings()


def test_lowering_registry_semantics_dimension():
    """The delays tier narrows every built-in's declaration: same native
    encodings, no 'sharded' (the halo exchange carries spike counts
    only), never silently widened."""
    for name in available_backends():
        sup = get_backend(name).supported_encodings(semantics="delays")
        assert sup, name
        assert "sharded" not in sup, name
    assert get_backend("ref").supported_encodings(semantics="delays") \
        == ("dense",)
    assert get_backend("sparse_pallas").supported_encodings(
        semantics="delays") == ("ell", "hybrid")


def test_unlowerable_semantics_combinations_raise():
    """Combinations outside the registry raise — no silent downgrade."""
    sysd = conftest.delayed_variant(paper_pi(True))
    # delayed rules under the paper's delay-free semantics
    with pytest.raises(ValueError, match="delay"):
        get_backend("ref").compile(sysd)
    # sharded × delays: refused at plan construction and at compile
    with pytest.raises(ValueError, match="shard"):
        SystemPlan.for_system(sysd, num_shards=2, semantics="delays")
    with pytest.raises(ValueError, match="delays"):
        compile_sharded(sysd, SystemPlan(num_shards=2, semantics="delays"))


# ---------------------------------------------------------------------------
# backend × encoding × semantics (single device): bit-identity to ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("system_name", SYSTEM_NAMES)
def test_backend_encoding_matrix_matches_ref(lowering_cell, system_name):
    """Walk every (backend, declared encoding, plan, semantics) cell of
    the registry (the shared ``lowering_cell`` fixture) and assert the
    expanded step equals the ref oracle bit-for-bit on valid entries —
    the interpret-mode kernels and the delayed tier included."""
    name, plan = lowering_cell
    system, T = conftest.EQUIV_SYSTEMS[system_name]
    if plan.semantics == "delays":
        system = conftest.delayed_variant(system)
    be = get_backend(name)
    ref = get_backend("ref")
    ref_plan = SystemPlan(encoding="dense", semantics=plan.semantics)
    cfgs = jnp.asarray(conftest.random_states(
        system, plan.semantics, batch=5,
        seed=abs(hash((name, system_name))) % 2**31))
    want = ref.expand(cfgs, ref.compile(system, plan=ref_plan), T)
    conftest.assert_same_step(
        want, be.expand(cfgs, be.compile(system, plan=plan), T))


# ---------------------------------------------------------------------------
# sharded × backend (faked 8-device mesh): the full matrix in one
# subprocess per workload — explore and distributed trace serving
# ---------------------------------------------------------------------------

def test_sharded_explore_matrix_matches_single_device_8dev():
    proc = _run(8, """
        import jax
        from repro.core import explore, paper_pi
        from repro.core.backend import PallasBackend, SparsePallasBackend
        from repro.core.distributed import explore_distributed
        from repro.core.generators import power_law
        from repro.sharding import neuron_axis

        assert len(jax.devices()) == 8
        cases = [
            # m=3 < 8 shards: most devices hold empty slices
            (paper_pi(True), dict(max_steps=12, frontier_cap=64,
                                  visited_cap=512, max_branches=16)),
            # heavy-tailed in-degree crossing every shard boundary
            (power_law(26, 3, seed=6),
             dict(max_steps=3, frontier_cap=128, visited_cap=1024,
                  max_branches=32)),
        ]
        backends = ["ref",
                    SparsePallasBackend(block_b=4, block_t=8),
                    PallasBackend(block_b=4, block_t=8, block_n=16)]
        for system, kw in cases:
            rs = explore(system, **kw)
            want = {tuple(r) for r in rs.configs}
            for be in backends:
                rd = explore_distributed(system, plan=neuron_axis(8),
                                         backend=be, **kw)
                nm = be if isinstance(be, str) else be.name
                assert {tuple(r) for r in rd.configs} == want, \\
                    (nm, system.name)
                assert rd.num_discovered == rs.num_discovered, \\
                    (nm, system.name)
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_distributed_traces_kernel_backends_bit_identical_8dev():
    proc = _run(8, """
        import numpy as np
        from repro.core import SystemPlan, paper_pi, run_traces
        from repro.core.backend import PallasBackend, SparsePallasBackend
        from repro.core.distributed import run_traces_distributed
        from repro.core.generators import power_law

        for system, plan, T in [
            (paper_pi(True), None, 16),
            # hybrid plan through the sparse kernel's COO stage
            (power_law(30, 3, seed=2),
             SystemPlan(encoding="hybrid", hub_threshold=2), 32),
        ]:
            for be in (PallasBackend(block_b=4, block_t=8, block_n=16),
                       SparsePallasBackend(block_b=4, block_t=8)):
                if plan is not None and be.name == "pallas":
                    continue          # hybrid is a sparse-family encoding
                ref = run_traces(system, steps=6, seeds=range(5),
                                 policy="random", max_branches=T,
                                 backend=be, plan=plan)
                got = run_traces_distributed(
                    system, steps=6, seeds=range(5), policy="random",
                    max_branches=T, backend=be, plan=plan)
                for a, b in zip(ref, got):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
