"""Failure-domain tests: fault injection, retry/bisect/degrade, deadlines,
admission control, and BFS checkpoint-resume (DESIGN.md §4.4).

The two acceptance scenarios from the PR contract:

* under a deterministic fault schedule (one poison request + two transient
  flush failures injected into a 64-request async burst), exactly the
  poison future fails and every other future resolves bit-identically to a
  fault-free synchronous ``drain()``;
* a killed-then-resumed ``explore`` restarted from its latest checkpoint
  returns the same archive as an uninterrupted run — for the single-device
  while-loop and for ``explore_distributed``.
"""

import time
import warnings

import numpy as np
import pytest

from repro.core import (SystemPlan, explore, get_backend, paper_pi,
                        run_trace, run_traces)
from repro.core import failover
from repro.core.backend import resolve_entry_info
from repro.core.distributed import explore_distributed
from repro.runtime.faults import (AdmissionRejected, DeadlineExceeded,
                                  FaultInjector, FaultPolicy, InjectedFault,
                                  PoisonError, run_supervised)
from repro.serve import SNPTraceService, TraceRequest

PI = paper_pi(True)
TIMEOUT = 120


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Degradation warns once per (from, to) edge per process; reset so
    every test observes its own first warning."""
    failover._WARNED.clear()
    yield
    failover._WARNED.clear()


# ---------------------------------------------------------------------------
# policy / injector primitives
# ---------------------------------------------------------------------------

def test_policy_validates_and_backoff_is_deterministic():
    with pytest.raises(ValueError):
        FaultPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        FaultPolicy(backoff_factor=0.5)
    pol = FaultPolicy(backoff_ms=10.0, backoff_factor=2.0, jitter=0.1)
    assert pol.backoff_s(0, token=7) == pol.backoff_s(0, token=7)
    assert pol.backoff_s(0) != pol.backoff_s(0, token="other")
    # exponential growth dominates the bounded jitter
    assert pol.backoff_s(3) > 2 * pol.backoff_s(1)
    assert FaultPolicy(jitter=0.0, backoff_ms=4.0).backoff_s(0) == 0.004


def test_injector_transient_fires_once_poison_fires_always():
    inj = FaultInjector(fail_calls=(2,), poison_seeds=(9,))
    assert inj.on_device_call(seeds=[1, 2]) == 1
    with pytest.raises(InjectedFault):
        inj.on_device_call(seeds=[1, 2])          # ordinal 2: transient
    assert inj.on_device_call(seeds=[1, 2]) == 3  # ...fired once
    with pytest.raises(PoisonError):
        inj.on_device_call(seeds=[1, 9])
    with pytest.raises(PoisonError):
        inj.on_device_call(seeds=[9])             # poison fires every time
    assert inj.injected == 3


def test_injector_rejects_poisoning_the_padding_seed():
    with pytest.raises(ValueError, match="padding"):
        FaultInjector(poison_seeds=(0,))


def test_transient_fault_not_masked_by_cobatched_poison():
    # a scheduled infrastructure fault outranks the poison payload riding
    # in the same batch; the poison then fires on the retry
    inj = FaultInjector(fail_calls=(1,), poison_seeds=(9,))
    with pytest.raises(InjectedFault) as ei:
        inj.on_device_call(seeds=[9])
    assert not isinstance(ei.value, PoisonError)
    with pytest.raises(PoisonError):
        inj.on_device_call(seeds=[9])


def test_run_supervised_bounds_restarts_and_chains_last_error():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError(f"boom {len(calls)}")
        return "done"

    out, restarts = run_supervised(flaky, max_restarts=3)
    assert out == "done" and restarts == 2

    def always():
        raise RuntimeError("always")

    with pytest.raises(RuntimeError, match="exceeded max_restarts=2"):
        run_supervised(always, max_restarts=2)


# ---------------------------------------------------------------------------
# degrade chain (core/failover)
# ---------------------------------------------------------------------------

def test_degrade_candidates_respect_encoding_compatibility():
    sp = get_backend("sparse_pallas")
    # ELL is a sparse-family encoding: only "sparse" can take over
    names = [b.name for b, _ in
             failover.degrade_candidates(sp, SystemPlan(encoding="ell"))]
    assert names == ["sparse"]
    # auto encoding: the whole tail of the chain qualifies
    names = [b.name for b, _ in
             failover.degrade_candidates(sp, SystemPlan(encoding="auto"))]
    assert names == ["pallas", "sparse", "ref"]
    # ref is the end of the line
    assert failover.degrade_candidates(
        get_backend("ref"), SystemPlan()) == []


def test_degraded_plans_drop_kernel_configs():
    from repro.core import KernelConfig
    sp = get_backend("sparse_pallas")
    for _, plan in failover.degrade_candidates(
            sp, SystemPlan(kernel=KernelConfig(block_b=4, block_t=8))):
        assert plan.kernel is None


def test_run_with_failover_walks_chain_and_records():
    events = []
    failover.add_degrade_listener(events.append)
    try:
        tried = []

        def attempt(be, plan):
            tried.append(be.name)
            if be.name == "sparse_pallas":
                raise RuntimeError("kernel exploded")
            return be.name

        with pytest.warns(RuntimeWarning, match="degrading"):
            got = failover.run_with_failover(
                attempt, get_backend("sparse_pallas"),
                SystemPlan(encoding="ell"), degradable=True)
        assert got == "sparse"
        assert tried == ["sparse_pallas", "sparse"]
        assert len(events) == 1
        assert (events[0].from_backend, events[0].to_backend) == \
            ("sparse_pallas", "sparse")
    finally:
        failover.remove_degrade_listener(events.append)


def test_run_with_failover_never_degrades_injected_faults():
    def attempt(be, plan):
        raise InjectedFault("node lost")

    with pytest.raises(InjectedFault):
        failover.run_with_failover(
            attempt, get_backend("sparse_pallas"), SystemPlan(),
            degradable=True)


def test_run_with_failover_passthrough_when_not_degradable():
    def attempt(be, plan):
        raise RuntimeError("explicit backend failure")

    # an explicitly requested backend is the caller's choice: no silent swap
    with pytest.raises(RuntimeError, match="explicit"):
        failover.run_with_failover(
            attempt, get_backend("sparse_pallas"), SystemPlan(),
            degradable=False)


def test_resolve_entry_info_marks_explicit_backends_unplanned():
    _, _, planned = resolve_entry_info(PI, "ref", None, workload=(4, 8))
    assert planned is False
    _, _, planned = resolve_entry_info(
        PI, None, SystemPlan(backend="ref"), workload=(4, 8))
    assert planned is False


# ---------------------------------------------------------------------------
# branch-overflow surfacing (engine -> TraceResult -> counters)
# ---------------------------------------------------------------------------

def test_branch_overflow_flag_surfaces_per_trace():
    out = run_trace(PI, steps=6, policy="first", max_branches=1)
    assert bool(np.any(np.asarray(out.branch_overflow)))
    big = run_trace(PI, steps=6, policy="first", max_branches=64)
    assert not np.any(np.asarray(big.branch_overflow))
    # batched: the flag is per trace per step, masked by liveness
    outs = run_traces(PI, steps=6, seeds=[0, 1], max_branches=1)
    assert np.asarray(outs.branch_overflow).shape == (2, 6)


def test_service_surfaces_truncation_in_result_and_stats():
    svc = SNPTraceService(batch_size=4, step_bucket=4)
    t_trunc = svc.submit(TraceRequest(PI, steps=5, policy="first",
                                      max_branches=1))
    t_ok = svc.submit(TraceRequest(PI, steps=5, policy="first",
                                   max_branches=64))
    res = svc.drain()
    assert res[t_trunc].truncated
    assert res[t_trunc].branch_overflow.shape == (5,)
    assert not res[t_ok].truncated
    assert svc.stats()["branch_overflow_traces"] == 1


# ---------------------------------------------------------------------------
# service failure domains: deadlines, admission, retry, bisect, degrade
# ---------------------------------------------------------------------------

def test_admission_control_rejects_at_submit():
    svc = SNPTraceService(batch_size=4,
                          policy=FaultPolicy(max_pending=2))
    svc.submit(TraceRequest(PI, steps=3, seed=1))
    svc.submit(TraceRequest(PI, steps=3, seed=2))
    with pytest.raises(AdmissionRejected):
        svc.submit(TraceRequest(PI, steps=3, seed=3))
    assert svc.stats()["rejected"] == 1
    svc.drain()                     # queue drains -> admission reopens
    svc.submit(TraceRequest(PI, steps=3, seed=3))


def test_expired_deadline_fails_fast_without_device_time():
    svc = SNPTraceService(batch_size=4,
                          policy=FaultPolicy(deadline_ms=1.0))
    t_dead = svc.submit(TraceRequest(PI, steps=3, seed=1))
    t_live = svc.submit(TraceRequest(PI, steps=3, seed=2,
                                     deadline_ms=60_000.0))
    time.sleep(0.02)                # both requests now older than 1 ms
    res = svc.drain()
    assert t_live in res and t_dead not in res
    assert isinstance(svc.last_failures[t_dead], DeadlineExceeded)
    assert svc.stats()["deadline_exceeded"] == 1


def test_retry_clears_transient_faults_sync():
    inj = FaultInjector(fail_calls=(1,))
    pol = FaultPolicy(max_retries=2, backoff_ms=0.0)
    svc = SNPTraceService(batch_size=4, policy=pol, fault_injector=inj)
    t = svc.submit(TraceRequest(PI, steps=4, policy="random", seed=3))
    res = svc.drain()
    ref = run_trace(PI, steps=4, policy="random", seed=3)
    np.testing.assert_array_equal(res[t].configs, np.asarray(ref.configs))
    s = svc.stats()
    assert s["retries"] == 1 and s["failed_calls"] == 1
    assert svc.last_failures == {}


def test_retry_exhaustion_propagates_last_exception():
    inj = FaultInjector(fail_calls=(1, 2),
                        error_factory=lambda n: InjectedFault(f"ordinal {n}"))
    pol = FaultPolicy(max_retries=1, backoff_ms=0.0, bisect=False,
                      degrade=False)
    svc = SNPTraceService(batch_size=4, policy=pol, fault_injector=inj)
    t = svc.submit(TraceRequest(PI, steps=3, seed=1))
    assert svc.drain() == {}
    # the failure carries the *last* attempt's exception, not the first
    assert "ordinal 2" in str(svc.last_failures[t])
    assert svc.stats()["failed_requests"] == 1


def test_bisection_isolates_poison_request_sync():
    poison_seed = 6
    inj = FaultInjector(poison_seeds=(poison_seed,))
    pol = FaultPolicy(max_retries=0, backoff_ms=0.0, bisect=True,
                      degrade=False)
    svc = SNPTraceService(batch_size=8, policy=pol, fault_injector=inj)
    tickets = {s: svc.submit(TraceRequest(PI, steps=4, policy="random",
                                          seed=s))
               for s in range(1, 9)}
    res = svc.drain()
    assert set(res) == {tickets[s] for s in range(1, 9) if s != poison_seed}
    assert isinstance(svc.last_failures[tickets[poison_seed]], PoisonError)
    for s, t in tickets.items():
        if s == poison_seed:
            continue
        ref = run_trace(PI, steps=4, policy="random", seed=s)
        np.testing.assert_array_equal(res[t].configs,
                                      np.asarray(ref.configs))
    s = svc.stats()
    assert s["bisections"] >= 1 and s["failed_requests"] == 1


def test_service_degrades_backend_and_counts_it():
    served_by = []

    def flaky_runner(comp, *, backend=None, **kw):
        be = get_backend(backend)
        if be.name == "sparse_pallas":
            raise RuntimeError("kernel exploded")
        served_by.append(be.name)
        return run_traces(comp, backend=be, **kw)

    pol = FaultPolicy(max_retries=0, backoff_ms=0.0, degrade=True,
                      bisect=False)
    svc = SNPTraceService(batch_size=4, backend="sparse_pallas",
                          policy=pol, runner=flaky_runner)
    t = svc.submit(TraceRequest(PI, steps=4, policy="random", seed=2))
    with pytest.warns(RuntimeWarning, match="degrading"):
        res = svc.drain()
    ref = run_trace(PI, steps=4, policy="random", seed=2, backend="ref")
    np.testing.assert_array_equal(res[t].configs, np.asarray(ref.configs))
    assert served_by == ["sparse"]       # ELL encoding -> sparse takes over
    assert svc.stats()["degraded"] == 1
    assert svc.last_failures == {}


def test_sync_drain_without_policy_stays_all_or_nothing():
    inj = FaultInjector(fail_calls=(1,))
    svc = SNPTraceService(batch_size=4, fault_injector=inj)
    t = svc.submit(TraceRequest(PI, steps=3, seed=1))
    with pytest.raises(InjectedFault):
        svc.drain()
    assert svc.pending == 1              # still queued: retry drain serves
    res = svc.drain()
    assert t in res


# ---------------------------------------------------------------------------
# the async acceptance scenario
# ---------------------------------------------------------------------------

def test_async_burst_poison_isolated_others_bit_identical():
    """One poison request + two transient flush failures in a 64-request
    async burst: exactly the poison future fails; every other future is
    bit-identical to a fault-free synchronous drain."""
    sync = SNPTraceService(batch_size=16)
    tickets = [sync.submit(TraceRequest(PI, steps=5, policy="random",
                                        seed=s + 1))
               for s in range(64)]
    baseline = sync.drain()

    poison_seed = 17
    inj = FaultInjector(fail_calls=(2, 4), poison_seeds=(poison_seed,))
    pol = FaultPolicy(max_retries=2, backoff_ms=0.0, bisect=True,
                      degrade=False)
    svc = SNPTraceService(batch_size=16, async_mode=True, max_delay_ms=0.0,
                          policy=pol, fault_injector=inj)
    futs = [svc.submit(TraceRequest(PI, steps=5, policy="random",
                                    seed=s + 1))
            for s in range(64)]
    svc.close()

    for s, (t, fut) in enumerate(zip(tickets, futs)):
        if s + 1 == poison_seed:
            with pytest.raises(PoisonError):
                fut.result(timeout=TIMEOUT)
            continue
        got, want = fut.result(timeout=TIMEOUT), baseline[t]
        np.testing.assert_array_equal(got.configs, want.configs)
        np.testing.assert_array_equal(got.emissions, want.emissions)
        np.testing.assert_array_equal(got.alive, want.alive)
        np.testing.assert_array_equal(got.branch_overflow,
                                      want.branch_overflow)
    s = svc.stats()
    assert s["failed_requests"] == 1 and s["retries"] >= 1 \
        and s["bisections"] >= 1
    assert s["traces_served"] == 63


def test_async_deadline_failure_reaches_the_future():
    pol = FaultPolicy(deadline_ms=1.0)
    svc = SNPTraceService(batch_size=4, async_mode=True, max_delay_ms=30.0,
                          policy=pol)
    fut = svc.submit(TraceRequest(PI, steps=3, seed=1))
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=TIMEOUT)    # flush fires ~30 ms > 1 ms deadline
    svc.close()


# ---------------------------------------------------------------------------
# async lifecycle edges
# ---------------------------------------------------------------------------

def test_drain_loop_never_waits_zero_with_max_delay_ms_zero():
    svc = SNPTraceService(batch_size=8, async_mode=True, max_delay_ms=0.0)
    orig_wait, bad_waits = svc._cv.wait, []

    def spying_wait(timeout=None):
        if timeout is not None and timeout <= 0:
            bad_waits.append(timeout)
        return orig_wait(timeout)

    svc._cv.wait = spying_wait
    try:
        futs = [svc.submit(TraceRequest(PI, steps=3, policy="random",
                                        seed=s))
                for s in range(24)]
        for fut in futs:
            fut.result(timeout=TIMEOUT)
    finally:
        svc.close()
        svc._cv.wait = orig_wait
    assert bad_waits == []


def test_close_races_in_flight_flush_and_futures_still_resolve():
    inj = FaultInjector(slow_calls={1: 0.2})
    svc = SNPTraceService(batch_size=4, async_mode=True, max_delay_ms=0.0,
                          fault_injector=inj)
    futs = [svc.submit(TraceRequest(PI, steps=3, policy="random", seed=s))
            for s in range(4)]
    svc.close()                      # joins the thread mid-stalled-flush
    for s, fut in enumerate(futs):
        ref = run_trace(PI, steps=3, policy="random", seed=s)
        np.testing.assert_array_equal(fut.result(timeout=TIMEOUT).configs,
                                      np.asarray(ref.configs))


def test_submit_after_close_raises():
    svc = SNPTraceService(batch_size=4, async_mode=True)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(TraceRequest(PI, steps=3))


def test_cancelled_future_skipped_during_bisecting_flush():
    poison_seed = 3
    inj = FaultInjector(poison_seeds=(poison_seed,))
    pol = FaultPolicy(max_retries=0, backoff_ms=0.0, bisect=True,
                      degrade=False)
    # a huge flush delay parks every request until close(): cancellation
    # deterministically beats the flush
    svc = SNPTraceService(batch_size=8, async_mode=True,
                          max_delay_ms=60_000.0, policy=pol,
                          fault_injector=inj)
    futs = [svc.submit(TraceRequest(PI, steps=4, policy="random",
                                    seed=s + 1))
            for s in range(8)]
    assert futs[0].cancel()
    svc.close()                      # flush runs recovery incl. bisection
    assert futs[0].cancelled()
    for s, fut in enumerate(futs[1:], start=1):
        if s + 1 == poison_seed:
            with pytest.raises(PoisonError):
                fut.result(timeout=TIMEOUT)
            continue
        ref = run_trace(PI, steps=4, policy="random", seed=s + 1)
        np.testing.assert_array_equal(fut.result(timeout=TIMEOUT).configs,
                                      np.asarray(ref.configs))


def test_legacy_runner_returning_three_tuple_still_serves():
    def legacy_runner(comp, **kw):
        out = run_traces(comp, **kw)
        return out.configs, out.emissions, out.alive    # pre-TraceOut shape

    svc = SNPTraceService(batch_size=4, runner=legacy_runner)
    t = svc.submit(TraceRequest(PI, steps=4, seed=1))
    res = svc.drain()[t]
    assert res.branch_overflow.shape == (4,)
    assert not res.truncated


# ---------------------------------------------------------------------------
# BFS checkpoint-resume
# ---------------------------------------------------------------------------

def _assert_same_explore(a, b):
    assert int(a.num_discovered) == int(b.num_discovered)
    np.testing.assert_array_equal(np.asarray(a.configs),
                                  np.asarray(b.configs))
    assert int(a.steps) == int(b.steps)
    assert bool(a.exhausted) == bool(b.exhausted)


def test_explore_checkpoints_are_pure_overhead_when_healthy(tmp_path):
    ref = explore(PI, max_steps=12, max_branches=64)
    got = explore(PI, max_steps=12, max_branches=64,
                  checkpoint_dir=str(tmp_path), checkpoint_every=3)
    _assert_same_explore(ref, got)


def test_explore_killed_and_resumed_matches_uninterrupted(tmp_path):
    ref = explore(PI, max_steps=12, max_branches=64)
    inj = FaultInjector(fail_calls=(2,))
    got, restarts = run_supervised(
        lambda: explore(PI, max_steps=12, max_branches=64,
                        checkpoint_dir=str(tmp_path), checkpoint_every=1,
                        fault_injector=inj),
        max_restarts=3)
    assert restarts == 1
    _assert_same_explore(ref, got)


def test_explore_distributed_killed_and_resumed_matches(tmp_path):
    ref = explore_distributed(PI, max_steps=12, max_branches=64)
    inj = FaultInjector(fail_calls=(2,))
    got, restarts = run_supervised(
        lambda: explore_distributed(PI, max_steps=12, max_branches=64,
                                    checkpoint_dir=str(tmp_path),
                                    checkpoint_every=1,
                                    fault_injector=inj),
        max_restarts=5)
    assert restarts == 1
    _assert_same_explore(ref, got)


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    inj = FaultInjector(fail_calls=(1, 2, 3, 4, 5, 6))
    with pytest.raises(RuntimeError, match="exceeded max_restarts"):
        run_supervised(
            lambda: explore(PI, max_steps=12, max_branches=64,
                            checkpoint_dir=str(tmp_path),
                            checkpoint_every=1, fault_injector=inj),
            max_restarts=2)
