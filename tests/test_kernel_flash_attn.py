"""Shape/dtype sweep for the flash-attention Pallas kernel vs. the
materialized-softmax oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import attention_ref, flash_attention

RNG = np.random.default_rng(42)


def _mk(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _check(B, Hq, Hkv, Sq, Skv, D, *, causal, dtype, bq=32, bk=32,
           kv_len=None):
    q = _mk((B, Hq, Sq, D), dtype)
    k = _mk((B, Hkv, Skv, D), dtype)
    v = _mk((B, Hkv, Skv, D), dtype)
    kl = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)
    got = flash_attention(q, k, v, kl, causal=causal, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v, kl, causal=causal)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_basic(dtype, causal):
    _check(2, 4, 2, 64, 64, 32, causal=causal, dtype=dtype)


@pytest.mark.parametrize("Hq,Hkv", [(8, 8), (8, 2), (8, 1), (15, 5)])
def test_gqa_ratios(Hq, Hkv):
    _check(1, Hq, Hkv, 64, 64, 32, causal=True, dtype=jnp.float32)


@pytest.mark.parametrize("Sq,Skv,bq,bk", [
    (64, 64, 64, 64),      # single tile
    (96, 96, 32, 32),      # multiple tiles
    (40, 72, 32, 32),      # padding on both axes
    (128, 256, 32, 64),    # rectangular (cross-attention style)
    (1, 128, 1, 64),       # decode-like single query
])
def test_shape_sweep(Sq, Skv, bq, bk):
    _check(2, 4, 2, Sq, Skv, 64, causal=(Sq == Skv), dtype=jnp.float32,
           bq=bq, bk=bk)


@pytest.mark.parametrize("D", [32, 64, 128])
def test_head_dims(D):
    _check(1, 4, 2, 64, 64, D, causal=True, dtype=jnp.float32)


def test_kv_length_masking():
    _check(3, 4, 2, 32, 128, 32, causal=False, dtype=jnp.float32,
           kv_len=[0, 57, 128])


def test_kv_len_zero_rows_are_zero():
    q = _mk((1, 2, 8, 16), jnp.float32)
    k = _mk((1, 2, 32, 16), jnp.float32)
    v = _mk((1, 2, 32, 16), jnp.float32)
    out = flash_attention(q, k, v, jnp.asarray([0], jnp.int32),
                          causal=False, block_q=8, block_k=16)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_gradients_match_reference():
    q = _mk((1, 2, 32, 16), jnp.float32)
    k = _mk((1, 1, 32, 16), jnp.float32)
    v = _mk((1, 1, 32, 16), jnp.float32)

    def loss_kernel(q, k, v):
        return (flash_attention(q, k, v, block_q=16, block_k=16) ** 2).sum()

    def loss_ref(q, k, v):
        return (attention_ref(q, k, v) ** 2).sum()

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_matches_upcast_float64_style_reference():
    """Numerical sanity at longer sequence (accumulation error bound)."""
    _check(1, 2, 1, 512, 512, 64, causal=True, dtype=jnp.float32,
           bq=128, bk=128)
