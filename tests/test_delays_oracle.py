"""Differential tests: the delayed-semantics engine vs the pure-Python
oracle (:mod:`tests.oracle`).

Every test drives the real entry points (``explore`` / ``run_trace`` with
``SystemPlan(semantics="delays")``) and compares *flat state rows*
bit-for-bit against the oracle's host-side enumeration — plus hand-built
scenarios where the expected states are written out literally, so the
oracle itself is pinned down and can't drift along with the engine.
"""

import numpy as np
import pytest

import oracle
from repro.core import (SystemPlan, Rule, SNPSystem, explore, paper_pi,
                        run_trace, with_delays)

BACKENDS = ("ref", "pallas", "sparse", "sparse_pallas")


def _plan(backend):
    enc = "dense" if backend in ("ref", "pallas") else "ell"
    return SystemPlan(semantics="delays", encoding=enc)


def engine_reachable(system, backend, max_steps=10, max_branches=64):
    res = explore(system, max_steps=max_steps, max_branches=max_branches,
                  backend=backend, plan=_plan(backend))
    rows = np.asarray(res.configs[:res.num_discovered])
    return set(map(tuple, rows.tolist())), bool(res.exhausted)


# ---------------------------------------------------------------------------
# Hand-built scenarios (expected states written out literally)
# ---------------------------------------------------------------------------

def test_pending_lands_on_reopen_and_d_step_closure():
    # n0 fires a d=2 rule: closed for exactly 2 steps, its spike lands on
    # n1 when it reopens — not before, not after.
    sysd = SNPSystem(
        num_neurons=2, initial_spikes=(1, 0),
        rules=(Rule(neuron=0, consume=1, produce=1, regex_base=1, delay=2),),
        synapses=((0, 1),), output_neuron=1, name="reopen")
    states, emis = oracle.run_deterministic(sysd, 4)
    assert states == [
        (0, 0, 2, 0, 1, 0),   # fired: consumed now, closed, pending stored
        (0, 0, 1, 0, 1, 0),   # still closed (countdown 2 -> 1)
        (0, 1, 0, 0, 0, 0),   # reopened: pending landed on n1
        (0, 1, 0, 0, 0, 0),   # halted (n1 has no rules)
    ]
    assert emis == [0, 0, 0, 0]
    for backend in BACKENDS:
        out = run_trace(sysd, steps=4, backend=backend, plan=_plan(backend))
        assert np.asarray(out.configs).tolist() == [list(s) for s in states]


def test_spikes_into_closed_neuron_are_lost():
    # n1 closes itself (d=3 forgetting rule) in the same step n0 spikes at
    # it — the spike is lost.  The zero-delay control receives it.
    rules = (Rule(neuron=0, consume=1, produce=1, regex_base=2, delay=0),
             Rule(neuron=1, consume=1, produce=0, regex_base=1, delay=3))
    sysd = SNPSystem(num_neurons=2, initial_spikes=(2, 1), rules=rules,
                     synapses=((0, 1),), name="loss")
    states, _ = oracle.run_deterministic(sysd, 4)
    assert states == [
        (1, 0, 0, 3, 0, 0),   # n0's spike vanished into closed n1
        (1, 0, 0, 2, 0, 0),
        (1, 0, 0, 1, 0, 0),
        (1, 0, 0, 0, 0, 0),   # reopened; nothing pending (forgetting rule)
    ]
    # zero-delay control: same wiring, n1's rule instant — spike arrives
    # (n1 forgets its own initial spike in step 1, then holds n0's).
    sys0 = with_delays(sysd, 0)
    states0, _ = oracle.run_deterministic(sys0, 2)
    assert states0[0] == (1, 1, 0, 0, 0, 0)
    for backend in BACKENDS:
        out = run_trace(sysd, steps=4, backend=backend, plan=_plan(backend))
        assert np.asarray(out.configs).tolist() == [list(s) for s in states]


def test_closed_neuron_suspends_applicability():
    # While closed, n0 holds spikes that match its rule but cannot fire;
    # the step is the deterministic countdown decrement (one successor).
    sysd = SNPSystem(
        num_neurons=2, initial_spikes=(2, 0),
        rules=(Rule(neuron=0, consume=1, produce=1, regex_base=1,
                    regex_period=1, covering=True, delay=2),),
        synapses=((0, 1),), name="suspend")
    s1 = ((1, 0), (2, 0), (1, 0))
    succ = oracle.successors(s1, sysd)
    assert succ == {(((1, 0), (1, 0), (1, 0)), 0)}  # no fire, just decrement
    # ...and on the reopen step the pending lands on n1; n0 can only
    # fire again the step after (rules stay suspended while reopening).
    states, _ = oracle.run_deterministic(sysd, 3)
    assert states == [
        (1, 0, 2, 0, 1, 0),
        (1, 0, 1, 0, 1, 0),
        (1, 1, 0, 0, 0, 0),
    ]


# ---------------------------------------------------------------------------
# Differential: engine (all four backends) vs oracle BFS
# ---------------------------------------------------------------------------

def _delay_variants():
    base = paper_pi()
    return [
        with_delays(base, 0),                     # all-zero: delay-free tier
        with_delays(base, 1),                     # uniform closure
        with_delays(base, lambda k, r: k % 3),    # mixed per-rule delays
        with_delays(base, (2, 0, 1, 0, 3)),       # explicit vector
    ]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", range(4))
def test_paper_pi_with_delays_matches_oracle(backend, variant):
    sysd = _delay_variants()[variant]
    want, want_done = oracle.explore(sysd, max_steps=8)
    got, got_done = engine_reachable(sysd, backend, max_steps=8)
    assert got == want
    assert got_done == want_done


def test_zero_delay_oracle_matches_no_delays_engine():
    # The oracle with all delays zero, projected onto the spikes slice,
    # is exactly the delay-free engine's reachable set.
    base = paper_pi()
    want, _ = oracle.explore(with_delays(base, 0), max_steps=8)
    m = base.num_neurons
    assert all(not any(row[m:]) for row in want)  # cd/pd stay zero
    res = explore(base, max_steps=8, backend="ref")
    rows = np.asarray(res.configs[:res.num_discovered])
    assert set(map(tuple, rows.tolist())) == {row[:m] for row in want}


def test_deterministic_emissions_match_oracle():
    # Delayed emission timing: the output neuron's spike reaches the
    # environment when it reopens, d steps after firing.
    sysd = SNPSystem(
        num_neurons=2, initial_spikes=(1, 1),
        rules=(Rule(neuron=0, consume=1, produce=1, regex_base=1, delay=0),
               Rule(neuron=1, consume=1, produce=1, regex_base=1,
                    regex_period=1, delay=2)),
        synapses=((0, 1),), output_neuron=1, name="emit-delayed")
    states, emis = oracle.run_deterministic(sysd, 6)
    assert emis[0] == 0          # fired with d=2: nothing out yet
    assert emis[2] == 1          # lands on reopen, two steps later
    for backend in BACKENDS:
        out = run_trace(sysd, steps=6, backend=backend, plan=_plan(backend))
        assert np.asarray(out.configs).tolist() == [list(s) for s in states]
        assert np.asarray(out.emissions).tolist() == emis
