"""Pure-Python oracle for the *delayed* SNP semantics.

A deliberately naive, dict-and-int, host-side implementation of the
general SNP transition (rules with firing delays, arXiv 1212.2529) in the
style of the paper's Algorithm 2: enumerate every nondeterministic rule
combination with ``itertools.product``, apply each one with plain loops
over neurons and synapses.  No jax, no matrices, no shared code with
``src/repro`` beyond the :class:`~repro.core.system.SNPSystem`
specification layer — so a differential test against it exercises every
layer of the vectorized implementation at once.

State here is a triple of int tuples ``(spikes, countdown, pending)``;
:func:`flatten` maps it onto the engine's flat ``3m`` row layout
``[spikes | countdown | pending]`` for bit-for-bit comparison.

Semantics (mirrors DESIGN.md "Delayed semantics"):

* a neuron with ``countdown > 0`` is **closed**: none of its rules are
  applicable, and spikes sent to it are **lost**;
* ``countdown == 1`` means the neuron reopens *this* transition: its
  pending spikes go out on its synapses (and to the environment if it is
  the output neuron) now, and it can receive again this step — but it
  cannot fire until the next step;
* firing a rule with delay ``d > 0`` consumes immediately, closes the
  neuron (``countdown := d``) and stores ``pending := produce``; firing
  with ``d == 0`` emits immediately (the paper's delay-free semantics).
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Set, Tuple

from repro.core.system import Rule, SNPSystem

State = Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]]

__all__ = ["applicable", "initial_state", "flatten", "successors",
           "explore", "run_deterministic"]


def applicable(spikes: int, r: Rule) -> bool:
    """Membership of ``a^spikes`` in ``L(E)`` plus the consume bound —
    same contract as ``repro.core.semantics.applicability`` but scalar."""
    if spikes < max(r.regex_base, r.consume):
        return False
    if r.covering:
        return True
    if r.regex_period > 0:
        return (spikes - r.regex_base) % r.regex_period == 0
    return spikes == r.regex_base


def initial_state(system: SNPSystem) -> State:
    m = system.num_neurons
    return (tuple(system.initial_spikes), (0,) * m, (0,) * m)


def flatten(state: State) -> Tuple[int, ...]:
    """The engine's flat row layout: ``[spikes | countdown | pending]``."""
    return state[0] + state[1] + state[2]


def successors(state: State, system: SNPSystem
               ) -> Set[Tuple[State, int]]:
    """All ``(next_state, emission)`` of one synchronous delayed step.

    Empty iff the state halts: no rule applicable anywhere *and* no
    countdown running (a closed neuron forces the deterministic
    countdown-decrement step even when nothing can fire).
    """
    spikes, cd, pd = state
    m = system.num_neurons
    per_neuron: List[List] = []
    for i in range(m):
        if cd[i] > 0:  # closed: rules suspended
            per_neuron.append([None])
            continue
        apps = [r for r in system.rules
                if r.neuron == i and applicable(spikes[i], r)]
        per_neuron.append(apps if apps else [None])
    if all(c == [None] for c in per_neuron) and not any(cd):
        return set()

    syn = set(system.synapses)
    out: Set[Tuple[State, int]] = set()
    for combo in itertools.product(*per_neuron):
        ns = list(spikes)
        ncd = [max(c - 1, 0) for c in cd]
        npd = list(pd)
        emit = [0] * m  # what each neuron puts on its synapses this step
        for i in range(m):
            if cd[i] == 1:  # reopening: pending spikes go out now
                emit[i] += pd[i]
                npd[i] = 0
        for r in combo:
            if r is None:
                continue
            ns[r.neuron] -= r.consume
            if r.delay == 0:
                emit[r.neuron] += r.produce
            else:  # close for d steps; spikes land on reopen
                ncd[r.neuron] = r.delay
                npd[r.neuron] = r.produce
        emission = emit[system.output_neuron] \
            if system.output_neuron >= 0 else 0
        for i in range(m):
            if not emit[i]:
                continue
            for j in range(m):
                # closed receivers lose the spikes (ncd is the *post*
                # countdown: a neuron that just reopened receives, a
                # neuron that just fired a delayed rule does not)
                if (i, j) in syn and ncd[j] == 0:
                    ns[j] += emit[i]
        out.add(((tuple(ns), tuple(ncd), tuple(npd)), emission))
    return out


def explore(system: SNPSystem, max_steps: int
            ) -> Tuple[Set[Tuple[int, ...]], bool]:
    """BFS over the delayed computation tree (paper Alg. 1, host-side):
    returns (flat reachable states incl. the initial one, exhausted?)."""
    init = flatten(initial_state(system))
    seen: Set[Tuple[int, ...]] = {init}
    frontier: Set[State] = {initial_state(system)}
    exhausted = False
    for _ in range(max_steps):
        nxt: Set[State] = set()
        for s in frontier:
            for succ, _ in successors(s, system):
                if flatten(succ) not in seen:
                    seen.add(flatten(succ))
                    nxt.add(succ)
        if not nxt:
            exhausted = True
            break
        frontier = nxt
    return seen, exhausted


def run_deterministic(system: SNPSystem, steps: int
                      ) -> Tuple[List[Tuple[int, ...]], List[int]]:
    """One trajectory of a *deterministic* delayed system (every state has
    at most one successor): returns (flat states after each step,
    emissions).  Raises if a state branches — use :func:`successors`
    directly for nondeterministic systems."""
    state = initial_state(system)
    states: List[Tuple[int, ...]] = []
    emissions: List[int] = []
    for _ in range(steps):
        succ = successors(state, system)
        if len(succ) > 1:
            raise ValueError(
                f"system {system.name!r} branches ({len(succ)} successors) "
                "— not deterministic")
        if not succ:  # halted: hold the state (engine serving convention)
            states.append(flatten(state))
            emissions.append(0)
            continue
        (state, emis), = succ
        states.append(flatten(state))
        emissions.append(emis)
    return states, emissions
