"""Elastic scaling end-to-end: train on an 8-device mesh, lose half the
fleet, restore the same checkpoint on 4 devices and keep training with
bit-identical data — the node-failure recovery path at (miniature) fleet
scale.  Runs in subprocesses with fake devices."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.smoke import reduced
from repro.data import DataConfig, make_batch
from repro.models import init_params
from repro.runtime import build_mesh, choose_mesh_shape
from repro.sharding import make_plan
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.checkpoint import save_checkpoint, restore_checkpoint

ndev = len(jax.devices())
mesh = build_mesh(choose_mesh_shape(ndev, model_axis=2))
plan = make_plan(mesh)
cfg = reduced(get_config("smollm-360m"))
opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
step = jax.jit(make_train_step(cfg, opt, remat="none",
                               constrain=plan.constrain))

def batch_for(s):
    return {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(seed=9), step=s, shard=0, batch=4,
        seq_len=32).items()}

params = init_params(jax.random.PRNGKey(0), cfg)
state = init_train_state(params, opt)
shardings = jax.tree.map(plan.named, plan.param_specs(cfg, state))

PHASE = "%s"
CKPT = "%s"
with mesh:
    if PHASE == "first":
        state = jax.device_put(state, shardings)
        for s in range(4):
            state, m = step(state, batch_for(s))
        save_checkpoint(CKPT, 4, jax.tree.map(np.asarray, state))
        for s in range(4, 8):
            state, m = step(state, batch_for(s))
        np.save(CKPT + "/ref_loss.npy", np.asarray(m["loss"]))
    else:
        template = jax.tree.map(np.zeros_like,
                                jax.tree.map(np.asarray, state))
        host, s0, _ = restore_checkpoint(CKPT, template)
        assert s0 == 4
        state = jax.device_put(host, shardings)   # NEW topology shardings
        for s in range(4, 8):
            state, m = step(state, batch_for(s))
        ref = float(np.load(CKPT + "/ref_loss.npy"))
        got = float(np.asarray(m["loss"]))
        assert abs(ref - got) < 5e-3, (ref, got)
        print("ELASTIC_OK", ref, got)
"""


def _run(ndev, phase, ckpt):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", SCRIPT % (phase, ckpt)],
        env=env, capture_output=True, text=True, timeout=600)


def test_restore_on_smaller_mesh(tmp_path):
    ckpt = str(tmp_path / "elastic")
    p1 = _run(8, "first", ckpt)
    assert p1.returncode == 0, p1.stderr[-3000:]
    p2 = _run(4, "resume", ckpt)   # half the devices "survive"
    assert p2.returncode == 0, p2.stderr[-3000:]
    assert "ELASTIC_OK" in p2.stdout
