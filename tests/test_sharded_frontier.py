"""Neuron-axis-sharded frontier tests (DESIGN.md §2).

The additive-hash algebra and the single-shard degenerate case run
in-process; multi-device equivalence against the single-device engine runs
in subprocesses with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(same convention as ``tests/test_distributed.py`` — the main pytest
process keeps the default single CPU device)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SystemPlan, compile_sharded, explore, paper_pi
from repro.core.distributed import explore_distributed
from repro.core.generators import power_law, random_system
from repro.core.hashing import zobrist_hash

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(ndev: int, body: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env, capture_output=True, text=True, timeout=600,
    )


# ---------------------------------------------------------------------------
# the additive hash the sharded dedup relies on
# ---------------------------------------------------------------------------

def test_zobrist_partials_add_up_to_the_full_hash():
    rng = np.random.default_rng(0)
    cfgs = jnp.asarray(rng.integers(0, 7, size=(5, 12)), jnp.int32)
    hi, lo = zobrist_hash(cfgs)
    for cuts in [(4, 8), (1, 2, 3), (6,), ()]:
        bounds = [0, *cuts, 12]
        phi = np.zeros(5, np.uint32)
        plo = np.zeros(5, np.uint32)
        for a, b in zip(bounds, bounds[1:]):
            h, l = zobrist_hash(cfgs[:, a:b], offset=a)
            phi += np.asarray(h)
            plo += np.asarray(l)
        np.testing.assert_array_equal(phi, np.asarray(hi))
        np.testing.assert_array_equal(plo, np.asarray(lo))


def test_zobrist_distinguishes_positions_and_values():
    a = jnp.asarray([[1, 0, 0], [0, 1, 0], [0, 0, 1], [2, 0, 0]], jnp.int32)
    hi, lo = zobrist_hash(a)
    pairs = set(zip(np.asarray(hi).tolist(), np.asarray(lo).tolist()))
    assert len(pairs) == 4


# ---------------------------------------------------------------------------
# compile_sharded structure
# ---------------------------------------------------------------------------

def test_compile_sharded_partitions_rules_and_halo():
    system = random_system(10, 2, 0.4, seed=2)
    sc = compile_sharded(system, SystemPlan(num_shards=4))
    S, mloc = sc.num_shards, sc.shard_size
    assert S == 4 and mloc == 3 and sc.num_neurons == 10
    a = sc.arrays
    assert a.rule_neuron.shape[0] == S
    # every send_idx entry is a real local neuron (or the mloc pad)
    si = np.asarray(a.send_idx)
    assert ((si >= 0) & (si <= mloc)).all()
    # in_idx points into [local | halo | zero] space
    z = mloc + S * sc.halo_width
    ii = np.asarray(a.in_idx)
    assert ((ii >= 0) & (ii <= z)).all()
    # the init slices reassemble C_0
    np.testing.assert_array_equal(
        np.asarray(sc.init_config), np.asarray(system.initial_spikes))


def test_explore_sharded_single_shard_matches_explore():
    """S=1 degenerate case in-process: no halo, psum over one device."""
    pi = paper_pi(True)
    kw = dict(max_steps=12, frontier_cap=64, visited_cap=512,
              max_branches=16)
    rs = explore(pi, **kw)
    sc = compile_sharded(pi, SystemPlan(num_shards=1))
    rd = explore_distributed(sc, **kw)
    assert {tuple(r) for r in rs.configs} == {tuple(r) for r in rd.configs}
    assert rs.num_discovered == rd.num_discovered


def test_sharded_plan_validates_mesh_and_backend():
    pi = paper_pi(True)
    with pytest.raises(ValueError, match="num_shards"):
        explore_distributed(pi, plan=SystemPlan(num_shards=3))  # 1 device
    # hybrid/dense x sharded are refused, never silently served as ELL
    with pytest.raises(ValueError, match="COO"):
        compile_sharded(pi, SystemPlan(encoding="hybrid", num_shards=2))
    with pytest.raises(ValueError, match="cannot be realized"):
        compile_sharded(pi, SystemPlan(encoding="dense", num_shards=2))
    # the auto-planner never pairs hybrid with a sharded run, even on the
    # heavy-tailed graphs that would pick hybrid single-device
    heavy = power_law(400, 3, seed=2)
    assert SystemPlan.for_system(heavy).encoding == "hybrid"
    auto = SystemPlan.for_system(heavy, num_shards=4)
    assert auto.encoding == "ell" and auto.num_shards == 4
    compile_sharded(heavy, auto)  # and that plan actually lowers
    # a backend whose lowering registry lacks 'sharded' is refused; the
    # built-in kernel backends all declare it (kernel-lowering layer)
    sc = compile_sharded(pi, SystemPlan(num_shards=1))

    class NoShardBackend:
        name = "no-shard"
        supports_nd_batch = True
        pad_multiple = 1
        materializes_spiking = False

        def supported_encodings(self):
            return ("dense",)

        def compile(self, system, plan=None):
            raise NotImplementedError

        def lower(self, compiled, plan):
            return compiled

        def expand(self, configs, comp, max_branches):
            raise NotImplementedError

    with pytest.raises(ValueError, match="sharded"):
        explore_distributed(sc, backend=NoShardBackend())
    from repro.core import supports_sharded
    for name in ("ref", "pallas", "sparse", "sparse_pallas"):
        from repro.core import get_backend
        assert supports_sharded(get_backend(name))
    with pytest.raises(ValueError, match="ShardedCompiled"):
        from repro.core import compile_system
        explore_distributed(compile_system(pi),
                            plan=SystemPlan(num_shards=2, encoding="ell"),
                            backend="sparse")


# ---------------------------------------------------------------------------
# multi-device equivalence vs the single-device engine (faked 8-dev mesh)
# ---------------------------------------------------------------------------

def test_sharded_frontier_matches_single_device_8dev():
    proc = _run(8, """
        import jax
        from repro.core import paper_pi, explore
        from repro.core.distributed import explore_distributed
        from repro.core.generators import power_law, random_system
        from repro.sharding import neuron_axis

        assert len(jax.devices()) == 8
        cases = [
            # m=3 < 8 shards: most devices hold empty slices
            (paper_pi(True), dict(max_steps=16, frontier_cap=64,
                                  visited_cap=512, max_branches=16)),
            (random_system(9, 2, 0.3, seed=1),
             dict(max_steps=8, frontier_cap=256, visited_cap=2048,
                  max_branches=64)),
            # heavy-tailed in-degree crossing every shard boundary
            (power_law(26, 3, seed=6),
             dict(max_steps=4, frontier_cap=128, visited_cap=1024,
                  max_branches=32)),
        ]
        for system, kw in cases:
            rs = explore(system, **kw)
            rd = explore_distributed(system, plan=neuron_axis(8), **kw)
            assert {tuple(r) for r in rd.configs} \\
                == {tuple(r) for r in rs.configs}, system.name
            assert rd.num_discovered == rs.num_discovered
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_sharded_frontier_overflow_is_flagged_and_sound_4dev():
    proc = _run(4, """
        from repro.core import explore
        from repro.core.distributed import explore_distributed
        from repro.core.generators import random_system
        from repro.sharding import neuron_axis

        system = random_system(9, 2, 0.3, seed=1)
        # tiny global frontier forces frontier overflow
        rd = explore_distributed(system, plan=neuron_axis(4), max_steps=6,
                                 frontier_cap=8, visited_cap=512,
                                 max_branches=64)
        assert rd.frontier_overflow and not rd.exhausted
        rs = explore(system, max_steps=10, frontier_cap=8192,
                     visited_cap=65536, max_branches=64)
        truth = {tuple(r) for r in rs.configs}
        assert {tuple(r) for r in rd.configs} <= truth
        print("OK")
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
